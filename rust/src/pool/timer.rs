//! Lazily-spawned monotonic timer (PR 6).
//!
//! One process-global thread over a min-heap of `(Instant, callback)`
//! entries backs both run deadlines ([`crate::graph::RunOptions::deadline`])
//! and bounded handle waits ([`crate::graph::RunHandle::wait_timeout`]).
//! The thread is spawned on the first [`schedule_at`] call — programs
//! that never use deadlines pay nothing — and then sleeps on a condvar
//! until the earliest entry is due (or a new, earlier entry arrives).
//!
//! Entries are fire-and-forget closures. The graph layer keeps them
//! self-defusing: a deadline entry holds a `Weak` to its run state plus
//! the launch generation, and checks both before promoting the abort
//! cause, so a stale entry for a completed (or re-armed, or dropped)
//! run is a no-op. Firing happens **outside** the heap lock — a
//! callback may itself schedule a new entry.
//!
//! Resolution is best-effort wall-clock (`Instant`-monotonic,
//! condvar-granular): entries never fire early, and under scheduler
//! noise they fire as soon after their due time as the thread runs.
//! That is exactly the cooperative-cancellation contract — the abort is
//! observed at the next node-dispatch boundary anyway.

use std::cmp::Ordering as CmpOrdering;
use std::collections::BinaryHeap;
use std::sync::{Condvar, Mutex, OnceLock};
use std::time::Instant;

/// A scheduled callback. Ordered so the **earliest** deadline is the
/// heap maximum (reverse comparison); `seq` breaks ties FIFO.
struct Entry {
    at: Instant,
    seq: u64,
    fire: Box<dyn FnOnce() + Send>,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl Eq for Entry {}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<CmpOrdering> {
        Some(self.cmp(other))
    }
}
impl Ord for Entry {
    fn cmp(&self, other: &Self) -> CmpOrdering {
        // Reversed on both keys: BinaryHeap is a max-heap, we want the
        // earliest (and, among equals, first-scheduled) entry on top.
        other.at.cmp(&self.at).then_with(|| other.seq.cmp(&self.seq))
    }
}

struct TimerState {
    heap: BinaryHeap<Entry>,
    next_seq: u64,
}

struct Timer {
    state: Mutex<TimerState>,
    cv: Condvar,
}

fn timer() -> &'static Timer {
    static TIMER: OnceLock<Timer> = OnceLock::new();
    TIMER.get_or_init(|| {
        let timer = Timer {
            state: Mutex::new(TimerState {
                heap: BinaryHeap::new(),
                next_seq: 0,
            }),
            cv: Condvar::new(),
        };
        std::thread::Builder::new()
            .name("graph-timer".to_string())
            .spawn(timer_loop)
            .expect("failed to spawn the timer thread");
        timer
    })
}

fn timer_loop() {
    let timer = timer();
    let mut guard = timer.state.lock().unwrap();
    loop {
        let now = Instant::now();
        match guard.heap.peek() {
            // Due: pop and fire outside the lock so a callback can
            // re-enter schedule_at without deadlocking.
            Some(entry) if entry.at <= now => {
                let entry = guard.heap.pop().unwrap();
                drop(guard);
                (entry.fire)();
                guard = timer.state.lock().unwrap();
            }
            // Pending: sleep until the earliest entry is due; a new
            // earlier entry notifies the condvar and re-enters here.
            Some(entry) => {
                let wait = entry.at - now;
                guard = timer.cv.wait_timeout(guard, wait).unwrap().0;
            }
            // Idle: park until something is scheduled. The thread is
            // global and never exits; an idle timer costs one parked
            // thread, which the lazy spawn already gated on first use.
            None => {
                guard = timer.cv.wait(guard).unwrap();
            }
        }
    }
}

/// Schedules `fire` to run on the timer thread at (or as soon as
/// possible after) `at`. Never fires early. Allocates the heap entry;
/// the deadline/wait-timeout paths are documented as outside the
/// zero-alloc re-run guarantee for exactly this reason.
pub(crate) fn schedule_at(at: Instant, fire: Box<dyn FnOnce() + Send>) {
    let t = timer();
    let mut state = t.state.lock().unwrap();
    let seq = state.next_seq;
    state.next_seq += 1;
    let is_new_min = match state.heap.peek() {
        Some(top) => at < top.at,
        None => true,
    };
    state.heap.push(Entry { at, seq, fire });
    drop(state);
    // Only a new minimum changes what the sleeping thread must do;
    // waking it for later entries would be harmless but noisy.
    if is_new_min {
        t.cv.notify_one();
    }
}

/// [`schedule_at`] with a relative delay — the common case for retry
/// backoff (PR 7, `serve/retry.rs`) and the parked wait backstops
/// (`thread_pool.rs`), where callers think in "this long from now"
/// rather than absolute instants.
pub(crate) fn schedule_after(delay: std::time::Duration, fire: Box<dyn FnOnce() + Send>) {
    schedule_at(Instant::now() + delay, fire);
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;
    use std::time::Duration;

    #[test]
    fn entries_fire_in_deadline_order_and_never_early() {
        let log = Arc::new(Mutex::new(Vec::new()));
        let start = Instant::now();
        // Schedule out of order; expect firing in deadline order.
        for (label, ms) in [("c", 60u64), ("a", 20), ("b", 40)] {
            let log = log.clone();
            schedule_at(
                start + Duration::from_millis(ms),
                Box::new(move || {
                    log.lock().unwrap().push((label, start.elapsed()));
                }),
            );
        }
        std::thread::sleep(Duration::from_millis(250));
        let log = log.lock().unwrap();
        let labels: Vec<_> = log.iter().map(|(l, _)| *l).collect();
        assert_eq!(labels, vec!["a", "b", "c"]);
        for (label, at) in log.iter() {
            let due = match *label {
                "a" => 20,
                "b" => 40,
                _ => 60,
            };
            assert!(
                *at >= Duration::from_millis(due),
                "{label} fired early: {at:?} < {due}ms"
            );
        }
    }

    #[test]
    fn callback_may_reschedule() {
        let hits = Arc::new(AtomicUsize::new(0));
        let h = hits.clone();
        schedule_at(
            Instant::now() + Duration::from_millis(5),
            Box::new(move || {
                let h2 = h.clone();
                h.fetch_add(1, Ordering::SeqCst);
                schedule_at(
                    Instant::now() + Duration::from_millis(5),
                    Box::new(move || {
                        h2.fetch_add(1, Ordering::SeqCst);
                    }),
                );
            }),
        );
        std::thread::sleep(Duration::from_millis(200));
        assert_eq!(hits.load(Ordering::SeqCst), 2);
    }
}
