//! Chase–Lev deque, fence-based C11 formulation (Lê–Pop–Cohen–Zappa
//! Nardelli, PPoPP '13) — the variant the paper *rejects* (§2.1).
//!
//! The paper observes that implementations using
//! `atomic_thread_fence` (the original C11 reference code, and
//! Taskflow's deque) trip ThreadSanitizer ("atomic_thread_fence is not
//! supported with -fsanitize=thread") and may produce false positives,
//! which is why the adopted deque ([`super::deque`]) expresses every
//! ordering on the atomic op itself. We keep this faithful port of the
//! fence formulation as (a) an ablation comparator for
//! `benches/ablations.rs` — same algorithm, different memory-order
//! style — and (b) the deque inside the Taskflow-proxy baseline
//! ([`crate::baseline::taskflow_like`]), mirroring what Taskflow runs.
//!
//! The port maps the paper's cited C11 lines one-to-one:
//! * `push`: relaxed loads, **release fence** before publishing bottom
//!   (the exact line the paper quotes from Taskflow), relaxed store.
//! * `pop`: relaxed bottom store then **seq_cst fence** (the store-load
//!   barrier), relaxed top load.
//! * `steal`: acquire top, **seq_cst fence**, acquire bottom, seq_cst
//!   CAS on top.
//!
//! Under Rust's memory model (same as C++11), `fence(Release)` followed
//! by a relaxed store synchronizes with an acquire load that reads it,
//! so this is correct — just fence-styled. Miri/TSan-style tooling is
//! expected to be unhappy with it, which is the paper's point.

use std::cell::Cell;
use std::marker::PhantomData;
use std::mem::MaybeUninit;
use std::ptr;
use std::sync::atomic::{fence, AtomicI64, AtomicPtr, Ordering};
use std::sync::{Arc, Mutex};

use crate::util::CachePadded;
pub use super::deque::Steal;

struct Buffer<T> {
    ptr: *mut MaybeUninit<T>,
    cap: usize,
}

impl<T> Buffer<T> {
    fn alloc(cap: usize) -> *mut Buffer<T> {
        let mut slots = Vec::<MaybeUninit<T>>::with_capacity(cap);
        // SAFETY: reserved above; slots stay uninitialized.
        unsafe { slots.set_len(cap) };
        let ptr = Box::into_raw(slots.into_boxed_slice()) as *mut MaybeUninit<T>;
        Box::into_raw(Box::new(Buffer { ptr, cap }))
    }

    /// # Safety: `buf` from `alloc`, not yet freed.
    unsafe fn dealloc(buf: *mut Buffer<T>) {
        let b = Box::from_raw(buf);
        drop(Vec::from_raw_parts(b.ptr, 0, b.cap));
    }

    #[inline]
    fn slot(&self, index: i64) -> *mut MaybeUninit<T> {
        unsafe { self.ptr.add(index as usize & (self.cap - 1)) }
    }
}

struct Inner<T> {
    top: CachePadded<AtomicI64>,
    bottom: CachePadded<AtomicI64>,
    buffer: AtomicPtr<Buffer<T>>,
    retired: Mutex<Vec<*mut Buffer<T>>>,
}

unsafe impl<T: Send> Send for Inner<T> {}
unsafe impl<T: Send> Sync for Inner<T> {}

impl<T> Drop for Inner<T> {
    fn drop(&mut self) {
        let top = self.top.load(Ordering::Relaxed);
        let bottom = self.bottom.load(Ordering::Relaxed);
        let buf = self.buffer.load(Ordering::Relaxed);
        unsafe {
            let mut i = top;
            while i < bottom {
                drop(ptr::read((*buf).slot(i)).assume_init());
                i += 1;
            }
            Buffer::dealloc(buf);
            for &old in self.retired.lock().unwrap().iter() {
                Buffer::dealloc(old);
            }
        }
    }
}

/// Owner handle (push/pop at the bottom).
pub struct FenceWorker<T> {
    inner: Arc<Inner<T>>,
    bottom_cache: Cell<i64>,
    _not_sync: PhantomData<*mut ()>,
}

unsafe impl<T: Send> Send for FenceWorker<T> {}

/// Thief handle (steal at the top).
pub struct FenceStealer<T> {
    inner: Arc<Inner<T>>,
}

impl<T> Clone for FenceStealer<T> {
    fn clone(&self) -> Self {
        FenceStealer {
            inner: self.inner.clone(),
        }
    }
}

/// Creates a fence-based deque, returning owner and thief handles.
pub fn fence_deque<T: Send>(min_capacity: usize) -> (FenceWorker<T>, FenceStealer<T>) {
    let cap = min_capacity.next_power_of_two().max(2);
    let inner = Arc::new(Inner {
        top: CachePadded::new(AtomicI64::new(0)),
        bottom: CachePadded::new(AtomicI64::new(0)),
        buffer: AtomicPtr::new(Buffer::<T>::alloc(cap)),
        retired: Mutex::new(Vec::new()),
    });
    (
        FenceWorker {
            inner: inner.clone(),
            bottom_cache: Cell::new(0),
            _not_sync: PhantomData,
        },
        FenceStealer { inner },
    )
}

impl<T: Send> FenceWorker<T> {
    /// Pushes at the bottom (owner-only), Lê et al. Fig. 1 `push`.
    pub fn push(&self, value: T) {
        let b = self.bottom_cache.get();
        let t = self.inner.top.load(Ordering::Acquire);
        let mut buf = self.inner.buffer.load(Ordering::Relaxed);
        unsafe {
            if b - t >= (*buf).cap as i64 {
                buf = self.grow(t, b, buf);
            }
            ptr::write((*buf).slot(b), MaybeUninit::new(value));
        }
        // The exact construction the paper quotes from Taskflow:
        //   atomic_thread_fence(release);
        //   bottom.store(b + 1, relaxed);
        fence(Ordering::Release);
        self.inner.bottom.store(b + 1, Ordering::Relaxed);
        self.bottom_cache.set(b + 1);
    }

    /// Pops from the bottom (owner-only), Lê et al. Fig. 1 `take`.
    pub fn pop(&self) -> Option<T> {
        let b = self.bottom_cache.get() - 1;
        let buf = self.inner.buffer.load(Ordering::Relaxed);
        self.inner.bottom.store(b, Ordering::Relaxed);
        // Store-load barrier between publishing bottom and reading top.
        fence(Ordering::SeqCst);
        let t = self.inner.top.load(Ordering::Relaxed);

        let result = if t <= b {
            // SAFETY: t..=b initialized; sole-element case validated by CAS.
            let value = unsafe { ptr::read((*buf).slot(b)) };
            if t == b {
                let won = self
                    .inner
                    .top
                    .compare_exchange(t, t + 1, Ordering::SeqCst, Ordering::Relaxed)
                    .is_ok();
                self.inner.bottom.store(b + 1, Ordering::Relaxed);
                self.bottom_cache.set(b + 1);
                // SAFETY: CAS success proves unique ownership of slot b.
                return if won { Some(unsafe { value.assume_init() }) } else { None };
            }
            // SAFETY: more than one element: slot b is exclusively ours.
            Some(unsafe { value.assume_init() })
        } else {
            self.inner.bottom.store(b + 1, Ordering::Relaxed);
            self.bottom_cache.set(b + 1);
            None
        };
        if result.is_some() {
            self.bottom_cache.set(b);
        }
        result
    }

    /// Owner-side length.
    pub fn len(&self) -> usize {
        let b = self.bottom_cache.get();
        let t = self.inner.top.load(Ordering::Relaxed);
        (b - t).max(0) as usize
    }

    /// Owner-side emptiness.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Returns a new thief handle.
    pub fn stealer(&self) -> FenceStealer<T> {
        FenceStealer {
            inner: self.inner.clone(),
        }
    }

    /// # Safety: owner-only; `old` is the current buffer, `t..b` live.
    unsafe fn grow(&self, t: i64, b: i64, old: *mut Buffer<T>) -> *mut Buffer<T> {
        let new = Buffer::<T>::alloc(((*old).cap * 2).max(2));
        let mut i = t;
        while i < b {
            ptr::copy_nonoverlapping((*old).slot(i), (*new).slot(i), 1);
            i += 1;
        }
        self.inner.buffer.store(new, Ordering::Release);
        self.inner.retired.lock().unwrap().push(old);
        new
    }
}

impl<T: Send> FenceStealer<T> {
    /// Steals from the top, Lê et al. Fig. 1 `steal`.
    pub fn steal(&self) -> Steal<T> {
        let t = self.inner.top.load(Ordering::Acquire);
        fence(Ordering::SeqCst);
        let b = self.inner.bottom.load(Ordering::Acquire);
        if t >= b {
            return Steal::Empty;
        }
        let buf = self.inner.buffer.load(Ordering::Acquire);
        // SAFETY: speculative; validated by the CAS before use.
        let value = unsafe { ptr::read((*buf).slot(t)) };
        if self
            .inner
            .top
            .compare_exchange(t, t + 1, Ordering::SeqCst, Ordering::Relaxed)
            .is_ok()
        {
            // SAFETY: CAS success proves index t belonged to us.
            Steal::Success(unsafe { value.assume_init() })
        } else {
            Steal::Retry
        }
    }

    /// Steals up to half of the victim's elements (bounded by
    /// [`super::deque::MAX_STEAL_BATCH`]), returning the first for
    /// immediate execution and pushing the rest onto `dest` — the
    /// fence-styled twin of [`super::deque::Stealer::steal_batch_and_pop`];
    /// see that method for why this is a loop of single-element CAS
    /// steals rather than one multi-slot top-CAS.
    pub fn steal_batch_and_pop(&self, dest: &FenceWorker<T>) -> Steal<T> {
        self.steal_batch_and_pop_counted(dest).0
    }

    /// [`FenceStealer::steal_batch_and_pop`] returning the extra count.
    pub fn steal_batch_and_pop_counted(&self, dest: &FenceWorker<T>) -> (Steal<T>, usize) {
        let t = self.inner.top.load(Ordering::Acquire);
        fence(Ordering::SeqCst);
        let b = self.inner.bottom.load(Ordering::Acquire);
        let available = b - t;
        if available <= 0 {
            return (Steal::Empty, 0);
        }
        let first = match self.steal() {
            Steal::Success(v) => v,
            other => return (other, 0),
        };
        let want = ((available as usize + 1) / 2)
            .min(super::deque::MAX_STEAL_BATCH)
            .saturating_sub(1);
        let mut extra = 0usize;
        while extra < want {
            match self.steal() {
                Steal::Success(v) => {
                    dest.push(v);
                    extra += 1;
                }
                _ => break,
            }
        }
        (Steal::Success(first), extra)
    }

    /// Approximate length.
    pub fn len(&self) -> usize {
        let t = self.inner.top.load(Ordering::Relaxed);
        let b = self.inner.bottom.load(Ordering::Relaxed);
        (b - t).max(0) as usize
    }

    /// Approximate emptiness.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn lifo_owner_fifo_thief() {
        let (w, s) = fence_deque::<i32>(4);
        for i in 0..6 {
            w.push(i);
        }
        assert_eq!(s.steal().success(), Some(0));
        assert_eq!(w.pop(), Some(5));
        assert_eq!(s.steal().success(), Some(1));
        assert_eq!(w.pop(), Some(4));
        assert_eq!(w.pop(), Some(3));
        assert_eq!(w.pop(), Some(2));
        assert_eq!(w.pop(), None);
        assert!(s.steal().is_empty());
    }

    #[test]
    fn grow_preserves_order() {
        let (w, s) = fence_deque::<usize>(2);
        for i in 0..129 {
            w.push(i);
        }
        for i in 0..129 {
            assert_eq!(s.steal().success(), Some(i));
        }
        assert!(s.steal().is_empty());
    }

    #[test]
    fn steal_batch_matches_fencefree_semantics() {
        let (victim, thief) = fence_deque::<usize>(16);
        let (mine, _s) = fence_deque::<usize>(16);
        for i in 0..10 {
            victim.push(i);
        }
        let (got, extra) = thief.steal_batch_and_pop_counted(&mine);
        assert_eq!(got.success(), Some(0));
        assert_eq!(extra, 4);
        assert_eq!(mine.len(), 4);
        assert_eq!(victim.len(), 5);
    }

    #[test]
    fn concurrent_no_loss_no_dup() {
        const ITEMS: usize = 10_000;
        let (w, s) = fence_deque::<usize>(8);
        let seen = Arc::new((0..ITEMS).map(|_| AtomicUsize::new(0)).collect::<Vec<_>>());
        let done = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let thief = {
            let (s, seen, done) = (s.clone(), seen.clone(), done.clone());
            std::thread::spawn(move || {
                let mut n = 0;
                loop {
                    match s.steal() {
                        Steal::Success(v) => {
                            seen[v].fetch_add(1, Ordering::Relaxed);
                            n += 1;
                        }
                        Steal::Empty if done.load(Ordering::Acquire) => break,
                        _ => std::hint::spin_loop(),
                    }
                }
                n
            })
        };
        let mut popped = 0;
        for i in 0..ITEMS {
            w.push(i);
            if i % 2 == 0 {
                if let Some(v) = w.pop() {
                    seen[v].fetch_add(1, Ordering::Relaxed);
                    popped += 1;
                }
            }
        }
        while let Some(v) = w.pop() {
            seen[v].fetch_add(1, Ordering::Relaxed);
            popped += 1;
        }
        done.store(true, Ordering::Release);
        let stolen = thief.join().unwrap();
        assert_eq!(popped + stolen, ITEMS);
        assert!(seen.iter().all(|c| c.load(Ordering::Relaxed) == 1));
    }
}
