//! Epoch-based eventcount: lets idle workers sleep without missed
//! wakeups and without taking a lock on the submit fast path.
//!
//! The paper's motivation (§1) is that idle workers must not burn CPU —
//! Fig. 2 (CPU time) is exactly the chart that punishes naive spinning.
//! The protocol is the classic eventcount (as in Eigen/Taskflow's
//! `Notifier`, simplified to a single condvar):
//!
//! * A would-be sleeper calls [`EventCount::prepare_wait`] (increments
//!   the waiter count, reads the epoch), then *re-checks its work
//!   sources*, and either [`EventCount::cancel_wait`]s (work appeared)
//!   or [`EventCount::commit_wait`]s (sleeps until the epoch moves).
//! * A producer publishes work, then calls [`EventCount::notify_one`] /
//!   [`notify_all`](EventCount::notify_all): if the waiter count is
//!   zero this is a single relaxed-ish load — no lock, no syscall.
//!
//! Correctness argument (all marked ops are `SeqCst`, so they are
//! totally ordered): if the producer reads `waiters == 0`, the sleeper's
//! increment came later in the total order, hence so did its re-check,
//! which then observes the published work (the publish is itself a
//! `SeqCst` store in the deque/injector). If the producer reads
//! `waiters > 0`, it bumps the epoch and acquires the mutex, which
//! serializes it against any sleeper between its epoch check and its
//! `Condvar::wait`, so the sleeper either sees the new epoch under the
//! lock or is already waiting and receives the notification.
//!
//! The pool instantiates **two** eventcounts: one for workers and
//! caller-assist helpers (woken by work arrival), and a separate one
//! for async-run-handle waiters (`PoolInner::wait_run`, woken only by
//! run completion). The split matters because a `notify_one` wakes an
//! arbitrary sleeper: a run waiter takes no work, so if it shared the
//! workers' eventcount it could absorb a work-arrival wakeup, re-park,
//! and leave the task stranded with the intended worker still asleep.
//! The same prepare/re-check/commit protocol (with the sleeper's
//! predicate being the run's SeqCst completion counter instead of the
//! queues) gives the same no-lost-wakeup guarantee; this handshake is
//! model-checked under loom in `rust/tests/loom_model.rs`.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};

/// See module docs.
#[derive(Debug, Default)]
pub struct EventCount {
    epoch: AtomicU64,
    waiters: AtomicUsize,
    mutex: Mutex<()>,
    cv: Condvar,
}

/// Token returned by [`EventCount::prepare_wait`]; consume it with
/// `commit_wait` or `cancel_wait`.
#[derive(Debug, Clone, Copy)]
#[must_use = "a prepared wait must be committed or cancelled"]
pub struct WaitToken {
    epoch: u64,
}

impl EventCount {
    /// Creates a new eventcount.
    pub fn new() -> Self {
        Self::default()
    }

    /// Registers this thread as a prospective sleeper and snapshots the
    /// epoch. The caller MUST re-check its work sources between this
    /// call and `commit_wait`.
    pub fn prepare_wait(&self) -> WaitToken {
        self.waiters.fetch_add(1, Ordering::SeqCst);
        WaitToken {
            epoch: self.epoch.load(Ordering::SeqCst),
        }
    }

    /// Aborts a prepared wait (work was found on re-check).
    pub fn cancel_wait(&self, _token: WaitToken) {
        self.waiters.fetch_sub(1, Ordering::SeqCst);
    }

    /// Sleeps until the epoch moves past the token's snapshot.
    pub fn commit_wait(&self, token: WaitToken) {
        let mut guard = self.mutex.lock().unwrap();
        while self.epoch.load(Ordering::SeqCst) == token.epoch {
            guard = self.cv.wait(guard).unwrap();
        }
        drop(guard);
        self.waiters.fetch_sub(1, Ordering::SeqCst);
    }

    /// Like `commit_wait` but returns after `timeout` even if nothing
    /// was notified (used for shutdown robustness in the pool loop).
    pub fn commit_wait_timeout(&self, token: WaitToken, timeout: std::time::Duration) {
        let deadline = std::time::Instant::now() + timeout;
        let mut guard = self.mutex.lock().unwrap();
        while self.epoch.load(Ordering::SeqCst) == token.epoch {
            let now = std::time::Instant::now();
            if now >= deadline {
                break;
            }
            let (g, _res) = self.cv.wait_timeout(guard, deadline - now).unwrap();
            guard = g;
        }
        drop(guard);
        self.waiters.fetch_sub(1, Ordering::SeqCst);
    }

    /// Wakes at least one sleeper, if any thread is (about to be)
    /// sleeping. O(1) load when there are no waiters.
    pub fn notify_one(&self) {
        if self.waiters.load(Ordering::SeqCst) == 0 {
            return;
        }
        self.epoch.fetch_add(1, Ordering::SeqCst);
        // Lock/unlock serializes with sleepers between their epoch
        // check and cv.wait — without this, the notify could fall into
        // that window and be lost.
        drop(self.mutex.lock().unwrap());
        self.cv.notify_one();
    }

    /// Wakes all sleepers (shutdown, wait_idle transitions).
    pub fn notify_all(&self) {
        if self.waiters.load(Ordering::SeqCst) == 0 {
            return;
        }
        self.epoch.fetch_add(1, Ordering::SeqCst);
        drop(self.mutex.lock().unwrap());
        self.cv.notify_all();
    }

    /// Current number of registered (prospective) sleepers.
    pub fn waiter_count(&self) -> usize {
        self.waiters.load(Ordering::SeqCst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicBool;
    use std::sync::Arc;
    use std::time::Duration;

    #[test]
    fn cancel_leaves_no_waiters() {
        let ec = EventCount::new();
        let t = ec.prepare_wait();
        assert_eq!(ec.waiter_count(), 1);
        ec.cancel_wait(t);
        assert_eq!(ec.waiter_count(), 0);
    }

    #[test]
    fn notify_wakes_committed_waiter() {
        let ec = Arc::new(EventCount::new());
        let woke = Arc::new(AtomicBool::new(false));
        let h = {
            let (ec, woke) = (ec.clone(), woke.clone());
            std::thread::spawn(move || {
                let t = ec.prepare_wait();
                ec.commit_wait(t);
                woke.store(true, Ordering::SeqCst);
            })
        };
        // Wait for the thread to register.
        while ec.waiter_count() == 0 {
            std::thread::yield_now();
        }
        ec.notify_one();
        h.join().unwrap();
        assert!(woke.load(Ordering::SeqCst));
    }

    #[test]
    fn notify_before_commit_is_not_lost() {
        // The classic missed-wakeup scenario: notification arrives
        // between prepare and commit. The epoch change must make
        // commit_wait return immediately.
        let ec = EventCount::new();
        let t = ec.prepare_wait();
        ec.epoch.fetch_add(1, Ordering::SeqCst); // simulate notify's epoch bump
        let start = std::time::Instant::now();
        ec.commit_wait(t);
        assert!(start.elapsed() < Duration::from_millis(100));
    }

    #[test]
    fn timeout_returns() {
        let ec = EventCount::new();
        let t = ec.prepare_wait();
        let start = std::time::Instant::now();
        ec.commit_wait_timeout(t, Duration::from_millis(20));
        assert!(start.elapsed() >= Duration::from_millis(15));
        assert_eq!(ec.waiter_count(), 0);
    }

    #[test]
    fn notify_all_wakes_everyone() {
        let ec = Arc::new(EventCount::new());
        let n = 4;
        let handles: Vec<_> = (0..n)
            .map(|_| {
                let ec = ec.clone();
                std::thread::spawn(move || {
                    let t = ec.prepare_wait();
                    ec.commit_wait(t);
                })
            })
            .collect();
        while ec.waiter_count() < n {
            std::thread::yield_now();
        }
        ec.notify_all();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(ec.waiter_count(), 0);
    }

    #[test]
    fn producer_consumer_no_lost_work() {
        // Stress the prepare/check/commit protocol against a flag.
        let ec = Arc::new(EventCount::new());
        let work = Arc::new(AtomicUsize::new(0));
        let done = Arc::new(AtomicBool::new(false));
        let consumed = Arc::new(AtomicUsize::new(0));
        const N: usize = 2_000;

        let consumer = {
            let (ec, work, done, consumed) = (ec.clone(), work.clone(), done.clone(), consumed.clone());
            std::thread::spawn(move || loop {
                // Drain.
                loop {
                    let w = work.load(Ordering::SeqCst);
                    if w == 0 {
                        break;
                    }
                    if work.compare_exchange(w, w - 1, Ordering::SeqCst, Ordering::SeqCst).is_ok() {
                        consumed.fetch_add(1, Ordering::SeqCst);
                    }
                }
                if consumed.load(Ordering::SeqCst) == N {
                    return;
                }
                let t = ec.prepare_wait();
                if work.load(Ordering::SeqCst) > 0 || done.load(Ordering::SeqCst) {
                    ec.cancel_wait(t);
                    continue;
                }
                ec.commit_wait_timeout(t, Duration::from_millis(100));
            })
        };

        for _ in 0..N {
            work.fetch_add(1, Ordering::SeqCst);
            ec.notify_one();
        }
        done.store(true, Ordering::SeqCst);
        ec.notify_all();
        consumer.join().unwrap();
        assert_eq!(consumed.load(Ordering::SeqCst), N);
    }
}
