//! The work-stealing thread pool (paper §2.1, §4.1).
//!
//! Architecture, mirroring the paper:
//!
//! * one Chase–Lev deque per worker ([`super::deque`], the fence-free
//!   variant);
//! * a global injector for submissions from non-worker threads;
//! * **thread-local worker registration**: instead of a map from thread
//!   id to queue index (the "typical approach" the paper calls out), a
//!   `thread_local!` slot identifies the current worker and its deque,
//!   so `submit` from inside a task pushes straight to the local deque
//!   with no lookup;
//! * an eventcount so idle workers sleep instead of spinning (this is
//!   what keeps Fig. 2's CPU-time curve close to wall-time × threads).
//!
//! Workers run: pop own deque → steal (injector + random-start sweep
//! over victims) → park. On shutdown the pool drains remaining work
//! before joining.
//!
//! # Hot-path design (PR 1)
//!
//! Three optimizations, each independently toggleable via
//! [`PoolConfig`] for the `ablations` bench:
//!
//! 1. **Inline task storage** ([`PoolConfig::inline_tasks`]) — tasks
//!    are [`RawTask`] cells: closures up to 3 words live inline, no
//!    heap allocation from submit to execute (see [`super::task`]).
//! 2. **Batched stealing** ([`PoolConfig::steal_batch`]) — a thief
//!    that finds a loaded victim takes up to half its run in one
//!    visit ([`Stealer::steal_batch_and_pop`]), then works locally
//!    instead of re-entering the steal sweep per task.
//! 3. **Throttled, batched wakeups** ([`PoolConfig::batched_wakeups`])
//!    — a burst of N ready tasks (graph fan-out, source submission)
//!    is published with one shared-counter bump and one wake instead
//!    of N of each; per-submit notifies remain O(1) loads when no
//!    worker is parked.
//!
//! The seed's single SeqCst `pending` counter — one contended RMW on
//! every submit *and* every completion — is replaced by per-worker
//! cache-padded `(submitted, completed)` cells (single-writer each)
//! plus one external-submitter cell. [`ThreadPool::wait_idle`] detects
//! quiescence with a two-pass scan (all `completed`, then all
//! `submitted`; equal sums ⇒ idle): any job whose completion the
//! first pass counted had its submission counted by the second, so
//! the test cannot report idle while work is in flight.

use std::cell::Cell;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use super::deque::{deque, Steal, Stealer, Worker};
use super::event_count::EventCount;
use super::injector::{Injector, LaneInjector, MutexInjector, SegQueue, DEFAULT_LANE, NUM_LANES};
use super::metrics::{PaddedMetrics, PoolSnapshot, WorkerMetrics};
use super::task::RawTask;
use crate::util::{CachePadded, XorShift64Star};

/// Which injector implementation backs external submissions.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum InjectorKind {
    /// `Mutex<VecDeque>` — default; injector is off the hot path.
    #[default]
    Mutex,
    /// Lock-free segmented queue — for injector-heavy workloads.
    LockFree,
}

/// Pool construction parameters.
#[derive(Debug, Clone)]
pub struct PoolConfig {
    /// Worker thread count. Defaults to
    /// `std::thread::available_parallelism()`.
    pub num_threads: usize,
    /// How many full find-task sweeps a worker performs before parking.
    /// Higher values trade CPU time (Fig. 2) for wakeup latency.
    pub spin_rounds: u32,
    /// Injector implementation.
    pub injector: InjectorKind,
    /// Name prefix for worker threads (shows up in profilers).
    pub thread_name: String,
    /// Store small closures inline in the task cell instead of boxing
    /// every task (hot-path optimization 1; `false` reproduces the
    /// seed's `Box<dyn FnOnce>`-per-task behaviour for ablations).
    pub inline_tasks: bool,
    /// Steal up to half of a victim's run per visit instead of one
    /// task at a time (hot-path optimization 2).
    pub steal_batch: bool,
    /// Publish bursts of ready tasks with a single counter bump and a
    /// single wake instead of per-task submission (hot-path
    /// optimization 3; applies to graph fan-out and source submission).
    pub batched_wakeups: bool,
}

impl Default for PoolConfig {
    fn default() -> Self {
        Self {
            num_threads: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            spin_rounds: 2,
            injector: InjectorKind::default(),
            thread_name: "scheduling-worker".to_string(),
            inline_tasks: true,
            steal_batch: true,
            batched_wakeups: true,
        }
    }
}

/// Thread-local identity of a worker: which pool it belongs to and a
/// pointer to its own deque. This is the paper's "thread-local variable
/// instead of a thread-id → queue-index map" (§2.1).
#[derive(Clone, Copy)]
struct LocalWorker {
    pool: *const PoolInner,
    queue: *const Worker<RawTask>,
    index: usize,
}

thread_local! {
    static LOCAL: Cell<Option<LocalWorker>> = const { Cell::new(None) };
    /// Pool this thread is currently *assisting* (a caller-assist
    /// graph run executing tasks on the submitting thread); null when
    /// not assisting. Lets the graph executor reject nested
    /// `TaskGraph::run` calls on the same pool deterministically — the
    /// same task must error whether a worker or a helper picked it up.
    static ASSISTING: Cell<*const ()> = const { Cell::new(std::ptr::null()) };
}

/// Clears the TLS registration even if the worker loop unwinds.
struct LocalGuard;

impl Drop for LocalGuard {
    fn drop(&mut self) {
        LOCAL.with(|l| l.set(None));
    }
}

/// Marks the current thread as assisting `pool` for the guard's
/// lifetime, restoring the previous value on drop (assist scopes for
/// different pools can nest: a helper-executed task may legitimately
/// run a graph on a *different* pool).
struct AssistGuard {
    prev: *const (),
}

impl AssistGuard {
    fn enter(pool: &PoolInner) -> Self {
        let ptr = pool as *const PoolInner as *const ();
        AssistGuard {
            prev: ASSISTING.with(|a| a.replace(ptr)),
        }
    }
}

impl Drop for AssistGuard {
    fn drop(&mut self) {
        let prev = self.prev;
        ASSISTING.with(|a| a.set(prev));
    }
}

/// One shard of the distributed pending-work counter. Monotone
/// counters (never decremented) are what make the two-pass quiescence
/// scan sound — see the module docs.
///
/// Writer discipline: cell `i < n` is written only by worker `i`
/// (submissions it makes, completions it executes), so the hot path
/// never contends on a shared line; cell `n` takes submissions from
/// non-worker threads and completions from caller-assist helper
/// threads (`run_helper_job`) — both off the worker hot path.
#[derive(Default)]
struct PendingCell {
    submitted: AtomicU64,
    completed: AtomicU64,
}

pub(crate) struct PoolInner {
    /// Global injection queue, split into [`NUM_LANES`] priority lanes
    /// (PR 4): untagged submissions use [`DEFAULT_LANE`]; graph runs
    /// with priority lanes enabled spread tasks by run class × node
    /// rank (`graph::schedule::lane_compose`). Workers and helpers pop
    /// most-urgent-first with a starvation-bounding reverse scan.
    injector: LaneInjector<RawTask>,
    stealers: Vec<Stealer<RawTask>>,
    metrics: Vec<PaddedMetrics>,
    ec: EventCount,
    /// Dedicated eventcount for threads blocked on a graph-run
    /// completion ([`PoolInner::wait_run`]). Separate from `ec` on
    /// purpose: run waiters do not take work, so letting them park on
    /// the workers' eventcount would let a work-arrival `notify_one`
    /// land on a waiter that just re-parks — with the task stranded
    /// and the worker it was meant for still asleep. Only run
    /// completions notify this one.
    run_ec: EventCount,
    /// `num_threads + 1` cells; see [`PendingCell`].
    counters: Vec<CachePadded<PendingCell>>,
    /// Tasks whose closure panicked (panics are contained per-job).
    panics: AtomicU64,
    shutdown: AtomicBool,
    /// Threads currently blocked in `wait_idle` (gates the completion-
    /// side wakeup check so the common case pays one load).
    idle_waiters: AtomicUsize,
    idle_mutex: Mutex<()>,
    idle_cv: Condvar,
    spin_rounds: u32,
    inline_tasks: bool,
    steal_batch: bool,
    batched_wakeups: bool,
}

/// The work-stealing thread pool (see module docs).
///
/// Dropping the pool drains already-submitted work, then joins the
/// workers. Use [`ThreadPool::wait_idle`] to block until all submitted
/// work (including work spawned by work) has finished.
pub struct ThreadPool {
    inner: Arc<PoolInner>,
    threads: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// Creates a pool with `num_threads` workers (0 is clamped to 1).
    pub fn new(num_threads: usize) -> Self {
        Self::with_config(PoolConfig {
            num_threads,
            ..PoolConfig::default()
        })
    }

    /// Creates a pool with `available_parallelism()` workers, like the
    /// paper's default constructor.
    pub fn with_default_threads() -> Self {
        Self::with_config(PoolConfig::default())
    }

    /// Creates a pool from a full [`PoolConfig`].
    pub fn with_config(config: PoolConfig) -> Self {
        let n = config.num_threads.max(1);
        let mut owners = Vec::with_capacity(n);
        let mut stealers = Vec::with_capacity(n);
        for _ in 0..n {
            let (w, s) = deque::<RawTask>(256);
            owners.push(w);
            stealers.push(s);
        }
        let kind = config.injector;
        let injector = LaneInjector::new(move || -> Box<dyn Injector<RawTask>> {
            match kind {
                InjectorKind::Mutex => Box::new(MutexInjector::new()),
                InjectorKind::LockFree => Box::new(SegQueue::new()),
            }
        });
        let inner = Arc::new(PoolInner {
            injector,
            stealers,
            // `n + 1` blocks: one per worker plus the shared helper
            // lane used by caller-assist threads (graph runs executing
            // tasks on the submitting thread) — see helper_lane().
            metrics: (0..n + 1).map(|_| PaddedMetrics::new(WorkerMetrics::default())).collect(),
            ec: EventCount::new(),
            run_ec: EventCount::new(),
            counters: (0..n + 1).map(|_| CachePadded::new(PendingCell::default())).collect(),
            panics: AtomicU64::new(0),
            shutdown: AtomicBool::new(false),
            idle_waiters: AtomicUsize::new(0),
            idle_mutex: Mutex::new(()),
            idle_cv: Condvar::new(),
            spin_rounds: config.spin_rounds,
            inline_tasks: config.inline_tasks,
            steal_batch: config.steal_batch,
            batched_wakeups: config.batched_wakeups,
        });
        let threads = owners
            .into_iter()
            .enumerate()
            .map(|(index, queue)| {
                let inner = inner.clone();
                std::thread::Builder::new()
                    .name(format!("{}-{index}", config.thread_name))
                    .spawn(move || worker_loop(inner, index, queue))
                    .expect("failed to spawn worker thread")
            })
            .collect();
        ThreadPool { inner, threads }
    }

    /// Submits a task — a function taking no arguments and returning
    /// nothing (paper §4.1); use captures for inputs/outputs. If called
    /// from a worker of *this* pool, pushes to that worker's own deque
    /// (no lock, no map lookup); otherwise goes through the injector.
    /// Closures capturing up to 3 words are stored without any heap
    /// allocation (see [`super::task`]).
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        let job = if self.inner.inline_tasks {
            RawTask::closure(f)
        } else {
            RawTask::boxed_closure(f)
        };
        self.inner.submit_job(job);
    }

    /// Blocks until every submitted job (and every job those jobs
    /// submitted, transitively) has finished.
    ///
    /// Must be called from outside the pool's tasks; calling it from
    /// inside a task of this pool — whether that task is executing on
    /// a worker thread or on a caller-assist helper — would deadlock
    /// (the calling task's own completion is never counted while it
    /// blocks) and panics in debug builds.
    pub fn wait_idle(&self) {
        debug_assert!(
            !self.inner.on_worker_thread() && !self.inner.on_assisting_thread(),
            "wait_idle called from inside a task of the same pool"
        );
        let inner = &*self.inner;
        if inner.quiescent() {
            return;
        }
        inner.idle_waiters.fetch_add(1, Ordering::SeqCst);
        let mut guard = inner.idle_mutex.lock().unwrap();
        while !inner.quiescent() {
            // Completions nudge the condvar at quiescence edges, but
            // that edge check is heuristic (a stale injector emptiness
            // flag can suppress it), so never sleep unboundedly on it.
            let (g, _) = inner
                .idle_cv
                .wait_timeout(guard, Duration::from_millis(1))
                .unwrap();
            guard = g;
        }
        drop(guard);
        inner.idle_waiters.fetch_sub(1, Ordering::SeqCst);
    }

    /// Number of worker threads.
    pub fn num_threads(&self) -> usize {
        self.inner.stealers.len()
    }

    /// Estimate of jobs submitted but not yet finished.
    ///
    /// Relaxed-read semantics (like [`ThreadPool::panic_count`]): the
    /// value is a snapshot of sharded counters taken without
    /// synchronization, exact only while the pool is externally
    /// quiescent. Use [`ThreadPool::wait_idle`] to synchronize.
    pub fn pending(&self) -> usize {
        let mut completed = 0u64;
        for c in &self.inner.counters {
            completed += c.completed.load(Ordering::Relaxed);
        }
        let mut submitted = 0u64;
        for c in &self.inner.counters {
            submitted += c.submitted.load(Ordering::Relaxed);
        }
        submitted.saturating_sub(completed) as usize
    }

    /// Number of tasks that panicked (panics are contained per-task and
    /// counted rather than tearing down the worker). Relaxed-read
    /// semantics, consistent with [`ThreadPool::pending`].
    pub fn panic_count(&self) -> u64 {
        self.inner.panics.load(Ordering::Relaxed)
    }

    /// Snapshot of scheduler metrics across workers. The last entry is
    /// the shared **helper lane**: work executed by caller-assist
    /// threads (graph runs helping from the submitting thread) rather
    /// than by a pool worker.
    pub fn metrics(&self) -> PoolSnapshot {
        PoolSnapshot {
            workers: self.inner.metrics.iter().map(|m| m.snapshot()).collect(),
        }
    }

    /// Worker index of the current thread if it belongs to this pool.
    pub fn current_worker(&self) -> Option<usize> {
        LOCAL.with(|l| match l.get() {
            Some(lw) if lw.pool == Arc::as_ptr(&self.inner) => Some(lw.index),
            _ => None,
        })
    }

    pub(crate) fn inner(&self) -> &Arc<PoolInner> {
        &self.inner
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.inner.shutdown.store(true, Ordering::SeqCst);
        self.inner.ec.notify_all();
        for t in self.threads.drain(..) {
            // A worker that parked between the store and the notify is
            // still woken: prepare_wait/notify ordering is SeqCst (see
            // event_count.rs docs), and workers re-check `shutdown`
            // after every wakeup.
            let _ = t.join();
        }
    }
}

impl std::fmt::Debug for ThreadPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ThreadPool")
            .field("num_threads", &self.num_threads())
            .field("pending", &self.pending())
            .finish()
    }
}

impl PoolInner {
    /// Per-worker metrics blocks (for the graph executor's inline-
    /// continuation counter).
    pub(crate) fn metrics(&self) -> &[PaddedMetrics] {
        &self.metrics
    }

    /// Counts a contained closure panic (called from the task vtable).
    pub(crate) fn note_panic(&self) {
        self.panics.fetch_add(1, Ordering::Relaxed);
    }

    /// True if the current thread is a worker of this pool.
    pub(crate) fn on_worker_thread(&self) -> bool {
        LOCAL.with(|l| matches!(l.get(), Some(lw) if std::ptr::eq(lw.pool, self)))
    }

    /// Index of the counter cell for non-worker submitters.
    #[inline]
    fn external_cell(&self) -> usize {
        self.counters.len() - 1
    }

    /// Schedules a job: local deque if on a worker of this pool,
    /// injector otherwise. The submitted-counter bump precedes the
    /// push so a job can never be findable (and completable) before
    /// it is counted — the quiescence scan depends on that order.
    pub(crate) fn submit_job(&self, job: RawTask) {
        self.submit_job_to(DEFAULT_LANE, job);
    }

    /// [`PoolInner::submit_job`] with an explicit injector lane for the
    /// cross-thread path. A worker's own deque has no lanes — the lane
    /// only matters when the task travels through the injector.
    pub(crate) fn submit_job_to(&self, lane: u8, job: RawTask) {
        LOCAL.with(|l| match l.get() {
            Some(lw) if std::ptr::eq(lw.pool, self) => {
                self.counters[lw.index].submitted.fetch_add(1, Ordering::Release);
                // SAFETY: `queue` points at the Worker owned by this
                // thread's worker_loop frame, which outlives any task
                // it executes; we are that task.
                unsafe { (*lw.queue).push(job) };
                self.metrics[lw.index].on_push();
            }
            _ => {
                self.counters[self.external_cell()].submitted.fetch_add(1, Ordering::Release);
                self.injector.push_to(lane, job);
            }
        });
        // O(1) load (no lock, no syscall) when nobody is parked.
        self.ec.notify_one();
    }

    /// Schedules a burst of jobs with one counter bump, one deque/
    /// injector push sequence, and one wake — the fan-out fast path
    /// (graph successors, source submission). Falls back to per-job
    /// [`PoolInner::submit_job`] when `batched_wakeups` is disabled.
    pub(crate) fn submit_job_batch<I>(&self, jobs: I)
    where
        I: ExactSizeIterator<Item = RawTask>,
    {
        if !self.batched_wakeups {
            for job in jobs {
                self.submit_job(job);
            }
            return;
        }
        let n = jobs.len();
        if n == 0 {
            return;
        }
        LOCAL.with(|l| match l.get() {
            Some(lw) if std::ptr::eq(lw.pool, self) => {
                // Count before publishing (see submit_job).
                self.counters[lw.index].submitted.fetch_add(n as u64, Ordering::Release);
                for job in jobs {
                    // SAFETY: as in submit_job.
                    unsafe { (*lw.queue).push(job) };
                }
                self.metrics[lw.index].on_push_n(n as u64);
            }
            _ => {
                self.counters[self.external_cell()].submitted.fetch_add(n as u64, Ordering::Release);
                let mut jobs = jobs;
                self.injector.push_batch_to(DEFAULT_LANE, &mut jobs);
            }
        });
        if n == 1 {
            self.ec.notify_one();
        } else {
            // One epoch bump + one broadcast instead of n wakes;
            // excess sleepers re-check their work sources and re-park.
            self.ec.notify_all();
        }
    }

    /// Priority-aware burst submission for graph nodes (PR 4): the
    /// graph executor hands over the ready node indices plus two
    /// callbacks — `lane_for` (the composed injector lane of a node)
    /// and `mk` (node index → `RawTask`).
    ///
    /// `ranked` means `nodes` is sorted by **descending** critical-path
    /// rank, and the burst must reach consumers most-critical-first in
    /// every queue discipline:
    ///
    /// * worker-local deque (LIFO for its owner) — pushed in *reverse*,
    ///   so the owner pops in descending rank;
    /// * injector lanes (FIFO) — pushed in the given order, grouped
    ///   into contiguous per-lane batches (`lane_for` is monotone
    ///   non-decreasing along a rank-sorted burst, so grouping is one
    ///   forward walk).
    ///
    /// Unranked bursts keep their discovery order; per-lane grouping
    /// then takes one filtering pass per lane. Counter/wake discipline
    /// is identical to [`PoolInner::submit_job_batch`], including the
    /// per-task fallback when batched wakeups are disabled.
    pub(crate) fn submit_node_burst(
        &self,
        nodes: &[usize],
        ranked: bool,
        lane_for: &dyn Fn(usize) -> u8,
        mk: &dyn Fn(usize) -> RawTask,
    ) {
        let n = nodes.len();
        if n == 0 {
            return;
        }
        if !self.batched_wakeups {
            // Per-task submission (ablation arm). Keep the LIFO
            // compensation: on a worker, later pushes pop first.
            if ranked && self.on_worker_thread() {
                for &node in nodes.iter().rev() {
                    self.submit_job_to(lane_for(node), mk(node));
                }
            } else {
                for &node in nodes {
                    self.submit_job_to(lane_for(node), mk(node));
                }
            }
            return;
        }
        LOCAL.with(|l| match l.get() {
            Some(lw) if std::ptr::eq(lw.pool, self) => {
                // Count before publishing (see submit_job).
                self.counters[lw.index].submitted.fetch_add(n as u64, Ordering::Release);
                let push = |node: usize| {
                    // SAFETY: as in submit_job.
                    unsafe { (*lw.queue).push(mk(node)) };
                };
                if ranked {
                    nodes.iter().rev().for_each(|&node| push(node));
                } else {
                    nodes.iter().for_each(|&node| push(node));
                }
                self.metrics[lw.index].on_push_n(n as u64);
            }
            _ => {
                self.counters[self.external_cell()].submitted.fetch_add(n as u64, Ordering::Release);
                if ranked {
                    // Contiguous per-lane runs of the rank-sorted burst.
                    let mut i = 0;
                    while i < n {
                        let lane = lane_for(nodes[i]);
                        let mut j = i + 1;
                        while j < n && lane_for(nodes[j]) == lane {
                            j += 1;
                        }
                        self.injector
                            .push_batch_to(lane, &mut nodes[i..j].iter().map(|&node| mk(node)));
                        i = j;
                    }
                } else {
                    for lane in 0..NUM_LANES as u8 {
                        let mut it = nodes
                            .iter()
                            .filter(|&&node| lane_for(node) == lane)
                            .map(|&node| mk(node))
                            .peekable();
                        if it.peek().is_some() {
                            self.injector.push_batch_to(lane, &mut it);
                        }
                    }
                }
            }
        });
        if n == 1 {
            self.ec.notify_one();
        } else {
            self.ec.notify_all();
        }
    }

    /// Called on the executing worker after a job finishes.
    fn finish_job(&self, index: usize) {
        self.counters[index].completed.fetch_add(1, Ordering::Release);
        // Cold path: only when a thread is blocked in wait_idle AND
        // this worker sees no remaining work nearby does it pay the
        // mutex for a precise wakeup. The waiter re-checks with the
        // authoritative two-pass scan (1 ms timeout backstop covers
        // the stale-emptiness-flag corner).
        if self.idle_waiters.load(Ordering::Acquire) != 0
            && self.stealers[index].is_empty()
            && self.injector.is_empty()
        {
            // Lock/unlock pairs with the check-then-wait in wait_idle.
            drop(self.idle_mutex.lock().unwrap());
            self.idle_cv.notify_all();
        }
    }

    /// Two-pass quiescence test: sum all `completed`, then all
    /// `submitted`; equality means every job counted as submitted has
    /// also completed. Any completion the first pass observed had its
    /// submission observed by the second (submit-inc happens-before
    /// completion-inc happens-before our acquiring read), so the test
    /// never reports idle while transitively-spawned work is in
    /// flight. See the module docs for the full argument.
    fn quiescent(&self) -> bool {
        let mut completed = 0u64;
        for c in &self.counters {
            completed += c.completed.load(Ordering::Acquire);
        }
        let mut submitted = 0u64;
        for c in &self.counters {
            submitted += c.submitted.load(Ordering::Acquire);
        }
        submitted == completed
    }

    /// One attempt to find work: own deque, then injector, then a
    /// random-start sweep over the other workers' deques (taking up to
    /// half a victim's run per visit when batched stealing is on).
    /// Returns `(job, saw_retry)`.
    fn find_task(
        &self,
        index: usize,
        local: &Worker<RawTask>,
        rng: &mut XorShift64Star,
    ) -> (Option<RawTask>, bool) {
        let m = &self.metrics[index];
        if let Some(job) = local.pop() {
            m.on_pop();
            return (Some(job), false);
        }
        if let Some(job) = self.injector.pop() {
            m.on_injector_pop();
            return (Some(job), false);
        }
        let n = self.stealers.len();
        let mut saw_retry = false;
        if n > 1 {
            let start = rng.next_below(n);
            for k in 0..n {
                let victim = (start + k) % n;
                if victim == index {
                    continue;
                }
                let result = if self.steal_batch {
                    let (result, extra) = self.stealers[victim].steal_batch_and_pop_counted(local);
                    if extra > 0 {
                        m.on_steal_batch(extra as u64);
                        // The moved tasks enter the local deque and are
                        // counted as pushes; their eventual pops keep
                        // executed() covering every task exactly once.
                        m.on_push_n(extra as u64);
                    }
                    result
                } else {
                    self.stealers[victim].steal()
                };
                match result {
                    Steal::Success(job) => {
                        m.on_steal();
                        return (Some(job), saw_retry);
                    }
                    Steal::Retry => {
                        m.on_steal_failure();
                        saw_retry = true;
                    }
                    Steal::Empty => {}
                }
            }
        }
        (None, saw_retry)
    }

    /// True if any work might be available (used to re-check before
    /// parking; conservative — may say true spuriously).
    fn any_work(&self) -> bool {
        !self.injector.is_empty() || self.stealers.iter().any(|s| !s.is_empty())
    }

    /// Metrics index of the shared helper lane (caller-assist threads).
    #[inline]
    pub(crate) fn helper_lane(&self) -> usize {
        self.stealers.len()
    }

    /// True if the current thread is inside an [`PoolInner::assist_until`]
    /// scope for *this* pool — i.e. a task picked up by a caller-assist
    /// helper is executing. Used (together with worker-thread detection)
    /// to reject nested graph runs on the same pool.
    pub(crate) fn on_assisting_thread(&self) -> bool {
        ASSISTING.with(|a| std::ptr::eq(a.get(), self as *const PoolInner as *const ()))
    }

    /// Wakes every parked worker *and* any caller-assist thread parked
    /// on the eventcount (the graph executor's run-complete signal).
    pub(crate) fn notify_all_workers(&self) {
        self.ec.notify_all();
    }

    /// Wakes every thread parked in [`PoolInner::wait_run`] — the
    /// graph executor's run-completion signal for async handles. O(1)
    /// load when nobody is parked.
    pub(crate) fn notify_run_waiters(&self) {
        self.run_ec.notify_all();
    }

    /// Blocks until `is_done()` reports true **without** executing
    /// pool tasks — the completion-wait of an async run handle
    /// (`graph::RunHandle::wait` / `Drop`). Parks on the dedicated
    /// run eventcount, so work-arrival wakeups meant for workers are
    /// never swallowed; `is_done` must become true through pool task
    /// execution followed by [`PoolInner::notify_run_waiters`] (the
    /// SeqCst store/load pair plus the eventcount's prepare/re-check
    /// protocol then guarantee a parked waiter observes it, and a 1 ms
    /// timeout backstop makes liveness independent of that reasoning).
    ///
    /// On a thread that is already executing a task of this pool (a
    /// worker, or a caller-assist helper mid-task), parking could
    /// starve the very queues the awaited run needs — handle `Drop`
    /// still must not return before quiescence, so here the wait
    /// *drains* instead: it executes pool tasks (every worker deque is
    /// reachable through its stealer) until `is_done` flips.
    pub(crate) fn wait_run(self: &Arc<Self>, is_done: impl Fn() -> bool) {
        if self.on_worker_thread() || self.on_assisting_thread() {
            let mut rng = XorShift64Star::from_entropy();
            while !is_done() {
                let (job, saw_retry) = self.helper_find_task(&mut rng);
                match job {
                    Some(job) => self.run_helper_job(job),
                    // A victim deque is mid-operation; retry shortly.
                    None if saw_retry => std::hint::spin_loop(),
                    // Remaining tasks of the run are executing on other
                    // threads; yield until they finish.
                    None => std::thread::yield_now(),
                }
            }
            return;
        }
        loop {
            if is_done() {
                return;
            }
            let token = self.run_ec.prepare_wait();
            if is_done() {
                self.run_ec.cancel_wait(token);
                return;
            }
            self.run_ec.commit_wait_timeout(token, Duration::from_millis(1));
        }
    }

    /// One find-task attempt for a caller-assist helper: injector
    /// first (graph sources and helper-submitted successors land
    /// there), then a random-start single-task steal sweep. Helpers
    /// own no deque, so no batched stealing. Returns `(job, saw_retry)`.
    fn helper_find_task(&self, rng: &mut XorShift64Star) -> (Option<RawTask>, bool) {
        let m = &self.metrics[self.helper_lane()];
        if let Some(job) = self.injector.pop() {
            m.on_injector_pop();
            return (Some(job), false);
        }
        let n = self.stealers.len();
        let start = rng.next_below(n);
        let mut saw_retry = false;
        for k in 0..n {
            match self.stealers[(start + k) % n].steal() {
                Steal::Success(job) => {
                    m.on_steal();
                    return (Some(job), saw_retry);
                }
                Steal::Retry => {
                    m.on_steal_failure();
                    saw_retry = true;
                }
                Steal::Empty => {}
            }
        }
        (None, saw_retry)
    }

    /// Executes one job on a helper (non-worker) thread: metrics go to
    /// the shared helper lane and the completion to the external
    /// counter cell, keeping the two-pass quiescence scan balanced.
    fn run_helper_job(self: &Arc<Self>, job: RawTask) {
        job.run(self, self.helper_lane());
        self.counters[self.external_cell()].completed.fetch_add(1, Ordering::Release);
        // Mirror finish_job's wait_idle nudge (helpers have no own
        // deque to check).
        if self.idle_waiters.load(Ordering::Acquire) != 0 && self.injector.is_empty() {
            drop(self.idle_mutex.lock().unwrap());
            self.idle_cv.notify_all();
        }
    }

    /// Caller-assisted execution (graph executor, PR 2): runs pool
    /// tasks on the **calling** thread until `done()` reports true,
    /// parking on the eventcount only when there is genuinely nothing
    /// to take. The caller must not be a worker of this pool.
    ///
    /// `done` must become true through pool task execution (the graph
    /// run's final decrement) and be followed by
    /// [`PoolInner::notify_all_workers`]; the SeqCst store/load pair
    /// plus the eventcount's prepare/re-check protocol then guarantee
    /// a parked helper observes it. A 1 ms timeout backstop (same as
    /// `wait_idle`) makes liveness independent of that reasoning.
    ///
    /// Note: helpers execute whatever the queues hold, so tasks
    /// unrelated to the caller's graph run may execute on this thread.
    pub(crate) fn assist_until(self: &Arc<Self>, done: impl Fn() -> bool) {
        debug_assert!(!self.on_worker_thread(), "assist_until on a worker thread");
        let _assisting = AssistGuard::enter(self);
        let mut rng = XorShift64Star::from_entropy();
        loop {
            if done() {
                return;
            }
            let (job, saw_retry) = self.helper_find_task(&mut rng);
            if let Some(job) = job {
                self.run_helper_job(job);
                continue;
            }
            if saw_retry {
                // A victim deque is mid-operation; back off a touch and
                // retry without parking.
                std::hint::spin_loop();
                continue;
            }
            let token = self.ec.prepare_wait();
            if done() || self.any_work() {
                self.ec.cancel_wait(token);
                continue;
            }
            self.ec.commit_wait_timeout(token, Duration::from_millis(1));
        }
    }

    /// Executes one job. Closure panics are contained inside the task
    /// vtable (counted via [`PoolInner::note_panic`]); graph nodes
    /// contain panics in `graph::execute_node`. (Executed counts are
    /// derived from pop/steal/injector counters — see metrics.rs.)
    pub(crate) fn run_job(self: &Arc<Self>, index: usize, job: RawTask) {
        job.run(self, index);
        self.finish_job(index);
    }
}

fn worker_loop(inner: Arc<PoolInner>, index: usize, queue: Worker<RawTask>) {
    LOCAL.with(|l| {
        l.set(Some(LocalWorker {
            pool: Arc::as_ptr(&inner),
            queue: &queue as *const Worker<RawTask>,
            index,
        }))
    });
    let _guard = LocalGuard;
    let mut rng = XorShift64Star::from_entropy();

    'outer: loop {
        // Work until dry, spinning through `spin_rounds` extra sweeps.
        let mut spins = 0;
        loop {
            let (job, saw_retry) = inner.find_task(index, &queue, &mut rng);
            match job {
                Some(job) => {
                    inner.run_job(index, job);
                    spins = 0;
                }
                None if saw_retry => {
                    // Someone is mid-operation on a victim deque;
                    // back off a touch and retry without parking.
                    std::hint::spin_loop();
                }
                None => {
                    spins += 1;
                    if spins > inner.spin_rounds {
                        break;
                    }
                    std::thread::yield_now();
                }
            }
        }

        // Park protocol: register as sleeper, re-check, sleep.
        let token = inner.ec.prepare_wait();
        if inner.shutdown.load(Ordering::SeqCst) {
            inner.ec.cancel_wait(token);
            // Drain remaining work before exiting so drop() does not
            // strand submitted tasks.
            while let (Some(job), _) = inner.find_task(index, &queue, &mut rng) {
                inner.run_job(index, job);
            }
            break 'outer;
        }
        if inner.any_work() {
            inner.ec.cancel_wait(token);
            continue;
        }
        inner.metrics[index].on_park();
        inner.ec.commit_wait(token);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::time::Duration;

    #[test]
    fn executes_submitted_tasks() {
        let pool = ThreadPool::new(2);
        let count = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let count = count.clone();
            pool.submit(move || {
                count.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_idle();
        assert_eq!(count.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn zero_threads_clamps_to_one() {
        let pool = ThreadPool::new(0);
        assert_eq!(pool.num_threads(), 1);
        let hit = Arc::new(AtomicUsize::new(0));
        let h = hit.clone();
        pool.submit(move || {
            h.fetch_add(1, Ordering::Relaxed);
        });
        pool.wait_idle();
        assert_eq!(hit.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn tasks_submitting_tasks() {
        // Recursive fan-out: each task spawns children; wait_idle must
        // cover transitively spawned work.
        let pool = Arc::new(ThreadPool::new(3));
        let count = Arc::new(AtomicUsize::new(0));
        fn spawn(pool: &Arc<ThreadPool>, count: &Arc<AtomicUsize>, depth: usize) {
            count.fetch_add(1, Ordering::Relaxed);
            if depth == 0 {
                return;
            }
            for _ in 0..2 {
                let (p, c) = (pool.clone(), count.clone());
                pool.submit(move || spawn(&p, &c, depth - 1));
            }
        }
        spawn(&pool, &count, 0); // count the root call manually
        let (p, c) = (pool.clone(), count.clone());
        pool.submit(move || spawn(&p, &c, 9));
        pool.wait_idle();
        // Root manual call (1) + full binary tree of depth 9 (2^10 - 1).
        assert_eq!(count.load(Ordering::Relaxed), 1 + (1 << 10) - 1);
    }

    #[test]
    fn worker_submit_uses_local_queue() {
        let pool = ThreadPool::new(1);
        let pushed = Arc::new(AtomicUsize::new(0));
        let p = pushed.clone();
        pool.submit(move || {
            p.fetch_add(1, Ordering::Relaxed);
        });
        pool.wait_idle();
        // Now submit from inside a task and check the metrics counted a
        // local push.
        let inner_done = Arc::new(AtomicUsize::new(0));
        let d = inner_done.clone();
        struct PoolPtr(*const ThreadPool);
        unsafe impl Send for PoolPtr {}
        let pp = PoolPtr(&pool as *const ThreadPool);
        pool.submit(move || {
            // Capture the whole wrapper (edition-2021 closures would
            // otherwise capture only the raw-pointer field).
            let pp = pp;
            // SAFETY: `pool` outlives this task; wait_idle below joins it.
            let pool = unsafe { &*pp.0 };
            let d2 = d.clone();
            pool.submit(move || {
                d2.fetch_add(1, Ordering::Relaxed);
            });
        });
        pool.wait_idle();
        assert_eq!(inner_done.load(Ordering::Relaxed), 1);
        assert!(pool.metrics().total().pushes >= 1, "inner submit should hit the local deque");
    }

    #[test]
    fn panicking_task_is_contained() {
        let pool = ThreadPool::new(2);
        pool.submit(|| panic!("boom"));
        let ok = Arc::new(AtomicUsize::new(0));
        let o = ok.clone();
        pool.submit(move || {
            o.fetch_add(1, Ordering::Relaxed);
        });
        pool.wait_idle();
        assert_eq!(pool.panic_count(), 1);
        assert_eq!(ok.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn boxed_panicking_task_is_contained() {
        // The spill path must contain panics identically.
        let pool = ThreadPool::with_config(PoolConfig {
            num_threads: 1,
            inline_tasks: false,
            ..PoolConfig::default()
        });
        pool.submit(|| panic!("boxed boom"));
        pool.wait_idle();
        assert_eq!(pool.panic_count(), 1);
    }

    #[test]
    fn drop_drains_submitted_work() {
        let count = Arc::new(AtomicUsize::new(0));
        {
            let pool = ThreadPool::new(2);
            for _ in 0..50 {
                let count = count.clone();
                pool.submit(move || {
                    std::thread::sleep(Duration::from_micros(100));
                    count.fetch_add(1, Ordering::Relaxed);
                });
            }
            // Drop without wait_idle.
        }
        assert_eq!(count.load(Ordering::Relaxed), 50);
    }

    #[test]
    fn wait_idle_on_idle_pool_returns_immediately() {
        let pool = ThreadPool::new(2);
        pool.wait_idle();
        pool.wait_idle();
    }

    #[test]
    fn pending_estimate_settles_to_zero() {
        let pool = ThreadPool::new(2);
        assert_eq!(pool.pending(), 0);
        let count = Arc::new(AtomicUsize::new(0));
        for _ in 0..64 {
            let c = count.clone();
            pool.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_idle();
        assert_eq!(pool.pending(), 0);
        assert_eq!(count.load(Ordering::Relaxed), 64);
    }

    #[test]
    fn current_worker_identity() {
        let pool = Arc::new(ThreadPool::new(2));
        assert_eq!(pool.current_worker(), None);
        let p = pool.clone();
        let (tx, rx) = std::sync::mpsc::channel();
        pool.submit(move || {
            tx.send(p.current_worker()).unwrap();
        });
        let idx = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(matches!(idx, Some(i) if i < 2));
        pool.wait_idle();
    }

    #[test]
    fn lock_free_injector_config() {
        let pool = ThreadPool::with_config(PoolConfig {
            num_threads: 2,
            injector: InjectorKind::LockFree,
            ..PoolConfig::default()
        });
        let count = Arc::new(AtomicUsize::new(0));
        for _ in 0..500 {
            let count = count.clone();
            pool.submit(move || {
                count.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_idle();
        assert_eq!(count.load(Ordering::Relaxed), 500);
    }

    #[test]
    fn many_waves_of_work_with_parking_between() {
        let pool = ThreadPool::new(2);
        let count = Arc::new(AtomicUsize::new(0));
        for wave in 0..20 {
            for _ in 0..10 {
                let count = count.clone();
                pool.submit(move || {
                    count.fetch_add(1, Ordering::Relaxed);
                });
            }
            pool.wait_idle();
            assert_eq!(count.load(Ordering::Relaxed), (wave + 1) * 10);
            // Let workers park so the next wave exercises wakeup.
            std::thread::sleep(Duration::from_millis(2));
        }
    }

    #[test]
    fn every_optimization_toggle_is_correct() {
        // The three hot-path optimizations must be behaviour-preserving
        // individually and in the all-off configuration.
        let variants: [(&str, PoolConfig); 5] = [
            ("all-on", PoolConfig::default()),
            ("boxed-tasks", PoolConfig { inline_tasks: false, ..PoolConfig::default() }),
            ("single-steal", PoolConfig { steal_batch: false, ..PoolConfig::default() }),
            ("per-task-wake", PoolConfig { batched_wakeups: false, ..PoolConfig::default() }),
            (
                "all-off",
                PoolConfig {
                    inline_tasks: false,
                    steal_batch: false,
                    batched_wakeups: false,
                    ..PoolConfig::default()
                },
            ),
        ];
        for (name, config) in variants {
            let pool = ThreadPool::with_config(PoolConfig { num_threads: 3, ..config });
            let count = Arc::new(AtomicUsize::new(0));
            for _ in 0..1000 {
                let c = count.clone();
                pool.submit(move || {
                    c.fetch_add(1, Ordering::Relaxed);
                });
            }
            pool.wait_idle();
            assert_eq!(count.load(Ordering::Relaxed), 1000, "{name}");
        }
    }

    #[test]
    fn metrics_include_shared_helper_lane() {
        // n worker lanes + 1 helper lane for caller-assist threads.
        let pool = ThreadPool::new(2);
        assert_eq!(pool.metrics().workers.len(), 3);
        assert_eq!(pool.inner().helper_lane(), 2);
    }

    #[test]
    fn assist_until_executes_queued_work_on_calling_thread() {
        // Pool with zero spinning and a task queued while we assist:
        // the helper must be able to drain it (possibly racing the
        // workers) and return as soon as `done` flips.
        let pool = ThreadPool::new(1);
        let count = Arc::new(AtomicUsize::new(0));
        for _ in 0..64 {
            let c = count.clone();
            pool.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        let c = count.clone();
        pool.inner().assist_until(move || c.load(Ordering::Relaxed) >= 64);
        assert_eq!(count.load(Ordering::Relaxed), 64);
        pool.wait_idle();
        assert_eq!(pool.pending(), 0);
    }

    #[test]
    fn wait_run_parks_until_predicate_flips() {
        // The non-assisting run-completion wait: the caller parks on
        // the dedicated run eventcount and is released by
        // notify_run_waiters (with the 1 ms backstop behind it).
        let pool = ThreadPool::new(2);
        let done = Arc::new(AtomicUsize::new(0));
        let d = done.clone();
        let inner = pool.inner().clone();
        pool.submit(move || {
            std::thread::sleep(Duration::from_millis(20));
            d.store(1, Ordering::SeqCst);
            inner.notify_run_waiters();
        });
        let d = done.clone();
        pool.inner().wait_run(|| d.load(Ordering::SeqCst) == 1);
        assert_eq!(done.load(Ordering::SeqCst), 1);
        pool.wait_idle();
    }

    #[test]
    fn wait_run_on_worker_thread_drains_tasks() {
        // From inside a pool task, wait_run must execute queued tasks
        // itself (parking the only worker would deadlock) — the
        // handle-dropped-on-a-worker path.
        let pool = Arc::new(ThreadPool::new(1));
        let (tx, rx) = std::sync::mpsc::channel();
        let p = pool.clone();
        pool.submit(move || {
            let hit = Arc::new(AtomicUsize::new(0));
            for _ in 0..8 {
                let h = hit.clone();
                p.submit(move || {
                    h.fetch_add(1, Ordering::SeqCst);
                });
            }
            let h = hit.clone();
            p.inner().wait_run(|| h.load(Ordering::SeqCst) == 8);
            tx.send(hit.load(Ordering::SeqCst)).unwrap();
        });
        assert_eq!(rx.recv_timeout(Duration::from_secs(10)).unwrap(), 8);
        pool.wait_idle();
    }

    #[test]
    fn batch_submit_from_external_thread() {
        // submit_job_batch through the injector path: counters, wake,
        // and delivery must all line up.
        let pool = ThreadPool::new(2);
        let count = Arc::new(AtomicUsize::new(0));
        let jobs: Vec<RawTask> = (0..100)
            .map(|_| {
                let c = count.clone();
                RawTask::closure(move || {
                    c.fetch_add(1, Ordering::Relaxed);
                })
            })
            .collect();
        pool.inner().submit_job_batch(jobs.into_iter());
        pool.wait_idle();
        assert_eq!(count.load(Ordering::Relaxed), 100);
        assert_eq!(pool.pending(), 0);
    }
}
