//! The work-stealing thread pool (paper §2.1, §4.1).
//!
//! Architecture, mirroring the paper:
//!
//! * one Chase–Lev deque per worker ([`super::deque`], the fence-free
//!   variant);
//! * a global injector for submissions from non-worker threads;
//! * **thread-local worker registration**: instead of a map from thread
//!   id to queue index (the "typical approach" the paper calls out), a
//!   `thread_local!` slot identifies the current worker and its deque,
//!   so `submit` from inside a task pushes straight to the local deque
//!   with no lookup;
//! * an eventcount so idle workers sleep instead of spinning (this is
//!   what keeps Fig. 2's CPU-time curve close to wall-time × threads).
//!
//! Workers run: pop own deque → steal (injector + random-start sweep
//! over victims) → park. On shutdown the pool drains remaining work
//! before joining.

use std::cell::Cell;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use super::deque::{deque, Steal, Stealer, Worker};
use super::event_count::EventCount;
use super::injector::{Injector, MutexInjector, SegQueue};
use super::metrics::{PaddedMetrics, PoolSnapshot, WorkerMetrics};
use crate::graph::NodeRun;
use crate::util::XorShift64Star;

/// A unit of work owned by the pool.
pub(crate) enum Job {
    /// A plain async task (paper §4.1).
    Closure(Box<dyn FnOnce() + Send + 'static>),
    /// A task-graph node (paper §2.2); executed via
    /// [`crate::graph::execute_node`], which may chain successors
    /// inline on this worker.
    Node(NodeRun),
}

/// Which injector implementation backs external submissions.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum InjectorKind {
    /// `Mutex<VecDeque>` — default; injector is off the hot path.
    #[default]
    Mutex,
    /// Lock-free segmented queue — for injector-heavy workloads.
    LockFree,
}

/// Pool construction parameters.
#[derive(Debug, Clone)]
pub struct PoolConfig {
    /// Worker thread count. Defaults to
    /// `std::thread::available_parallelism()`.
    pub num_threads: usize,
    /// How many full find-task sweeps a worker performs before parking.
    /// Higher values trade CPU time (Fig. 2) for wakeup latency.
    pub spin_rounds: u32,
    /// Injector implementation.
    pub injector: InjectorKind,
    /// Name prefix for worker threads (shows up in profilers).
    pub thread_name: String,
}

impl Default for PoolConfig {
    fn default() -> Self {
        Self {
            num_threads: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            spin_rounds: 2,
            injector: InjectorKind::default(),
            thread_name: "scheduling-worker".to_string(),
        }
    }
}

/// Thread-local identity of a worker: which pool it belongs to and a
/// pointer to its own deque. This is the paper's "thread-local variable
/// instead of a thread-id → queue-index map" (§2.1).
#[derive(Clone, Copy)]
struct LocalWorker {
    pool: *const PoolInner,
    queue: *const Worker<Job>,
    index: usize,
}

thread_local! {
    static LOCAL: Cell<Option<LocalWorker>> = const { Cell::new(None) };
}

/// Clears the TLS registration even if the worker loop unwinds.
struct LocalGuard;

impl Drop for LocalGuard {
    fn drop(&mut self) {
        LOCAL.with(|l| l.set(None));
    }
}

pub(crate) struct PoolInner {
    injector: Box<dyn Injector<Job>>,
    stealers: Vec<Stealer<Job>>,
    metrics: Vec<PaddedMetrics>,
    ec: EventCount,
    /// Jobs submitted but not yet finished executing.
    pending: AtomicUsize,
    /// Tasks whose closure panicked (panics are contained per-job).
    panics: AtomicU64,
    shutdown: AtomicBool,
    idle_mutex: Mutex<()>,
    idle_cv: Condvar,
    spin_rounds: u32,
}

/// The work-stealing thread pool (see module docs).
///
/// Dropping the pool drains already-submitted work, then joins the
/// workers. Use [`ThreadPool::wait_idle`] to block until all submitted
/// work (including work spawned by work) has finished.
pub struct ThreadPool {
    inner: Arc<PoolInner>,
    threads: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// Creates a pool with `num_threads` workers (0 is clamped to 1).
    pub fn new(num_threads: usize) -> Self {
        Self::with_config(PoolConfig {
            num_threads,
            ..PoolConfig::default()
        })
    }

    /// Creates a pool with `available_parallelism()` workers, like the
    /// paper's default constructor.
    pub fn with_default_threads() -> Self {
        Self::with_config(PoolConfig::default())
    }

    /// Creates a pool from a full [`PoolConfig`].
    pub fn with_config(config: PoolConfig) -> Self {
        let n = config.num_threads.max(1);
        let mut owners = Vec::with_capacity(n);
        let mut stealers = Vec::with_capacity(n);
        for _ in 0..n {
            let (w, s) = deque::<Job>(256);
            owners.push(w);
            stealers.push(s);
        }
        let injector: Box<dyn Injector<Job>> = match config.injector {
            InjectorKind::Mutex => Box::new(MutexInjector::new()),
            InjectorKind::LockFree => Box::new(SegQueue::new()),
        };
        let inner = Arc::new(PoolInner {
            injector,
            stealers,
            metrics: (0..n).map(|_| PaddedMetrics::new(WorkerMetrics::default())).collect(),
            ec: EventCount::new(),
            pending: AtomicUsize::new(0),
            panics: AtomicU64::new(0),
            shutdown: AtomicBool::new(false),
            idle_mutex: Mutex::new(()),
            idle_cv: Condvar::new(),
            spin_rounds: config.spin_rounds,
        });
        let threads = owners
            .into_iter()
            .enumerate()
            .map(|(index, queue)| {
                let inner = inner.clone();
                std::thread::Builder::new()
                    .name(format!("{}-{index}", config.thread_name))
                    .spawn(move || worker_loop(inner, index, queue))
                    .expect("failed to spawn worker thread")
            })
            .collect();
        ThreadPool { inner, threads }
    }

    /// Submits a task — a function taking no arguments and returning
    /// nothing (paper §4.1); use captures for inputs/outputs. If called
    /// from a worker of *this* pool, pushes to that worker's own deque
    /// (no lock, no map lookup); otherwise goes through the injector.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.inner.submit_job(Job::Closure(Box::new(f)));
    }

    /// Blocks until every submitted job (and every job those jobs
    /// submitted, transitively) has finished.
    ///
    /// Must be called from a non-worker thread; calling it from inside
    /// a task of this pool would deadlock and panics in debug builds.
    pub fn wait_idle(&self) {
        debug_assert!(
            !self.inner.on_worker_thread(),
            "wait_idle called from a worker task of the same pool"
        );
        let mut guard = self.inner.idle_mutex.lock().unwrap();
        while self.inner.pending.load(Ordering::SeqCst) != 0 {
            guard = self.inner.idle_cv.wait(guard).unwrap();
        }
    }

    /// Number of worker threads.
    pub fn num_threads(&self) -> usize {
        self.inner.stealers.len()
    }

    /// Jobs submitted but not yet finished.
    pub fn pending(&self) -> usize {
        self.inner.pending.load(Ordering::SeqCst)
    }

    /// Number of tasks that panicked (panics are contained per-task and
    /// counted rather than tearing down the worker).
    pub fn panic_count(&self) -> u64 {
        self.inner.panics.load(Ordering::Relaxed)
    }

    /// Snapshot of scheduler metrics across workers.
    pub fn metrics(&self) -> PoolSnapshot {
        PoolSnapshot {
            workers: self.inner.metrics.iter().map(|m| m.snapshot()).collect(),
        }
    }

    /// Worker index of the current thread if it belongs to this pool.
    pub fn current_worker(&self) -> Option<usize> {
        LOCAL.with(|l| match l.get() {
            Some(lw) if lw.pool == Arc::as_ptr(&self.inner) => Some(lw.index),
            _ => None,
        })
    }

    pub(crate) fn inner(&self) -> &Arc<PoolInner> {
        &self.inner
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.inner.shutdown.store(true, Ordering::SeqCst);
        self.inner.ec.notify_all();
        for t in self.threads.drain(..) {
            // A worker that parked between the store and the notify is
            // still woken: prepare_wait/notify ordering is SeqCst (see
            // event_count.rs docs), and workers re-check `shutdown`
            // after every wakeup.
            let _ = t.join();
        }
    }
}

impl std::fmt::Debug for ThreadPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ThreadPool")
            .field("num_threads", &self.num_threads())
            .field("pending", &self.pending())
            .finish()
    }
}

impl PoolInner {
    /// Per-worker metrics blocks (for the graph executor's inline-
    /// continuation counter).
    pub(crate) fn metrics(&self) -> &[PaddedMetrics] {
        &self.metrics
    }

    /// True if the current thread is a worker of this pool.
    fn on_worker_thread(&self) -> bool {
        LOCAL.with(|l| matches!(l.get(), Some(lw) if std::ptr::eq(lw.pool, self)))
    }

    /// Schedules a job: local deque if on a worker of this pool,
    /// injector otherwise. Wakes one sleeper.
    pub(crate) fn submit_job(&self, job: Job) {
        self.pending.fetch_add(1, Ordering::SeqCst);
        let leftover = LOCAL.with(|l| match l.get() {
            Some(lw) if std::ptr::eq(lw.pool, self) => {
                // SAFETY: `queue` points at the Worker owned by this
                // thread's worker_loop frame, which outlives any task
                // it executes; we are that task.
                unsafe { (*lw.queue).push(job) };
                self.metrics[lw.index].on_push();
                None
            }
            _ => Some(job),
        });
        if let Some(job) = leftover {
            self.injector.push(job);
        }
        self.ec.notify_one();
    }

    /// Called after a job finishes; wakes `wait_idle` on the last one.
    fn finish_job(&self) {
        if self.pending.fetch_sub(1, Ordering::SeqCst) == 1 {
            // Lock/unlock pairs with the check-then-wait in wait_idle.
            drop(self.idle_mutex.lock().unwrap());
            self.idle_cv.notify_all();
        }
    }

    /// One attempt to find work: own deque, then injector, then a
    /// random-start sweep over the other workers' deques.
    /// Returns `(job, saw_retry)`.
    fn find_task(
        &self,
        index: usize,
        local: &Worker<Job>,
        rng: &mut XorShift64Star,
    ) -> (Option<Job>, bool) {
        let m = &self.metrics[index];
        if let Some(job) = local.pop() {
            m.on_pop();
            return (Some(job), false);
        }
        if let Some(job) = self.injector.pop() {
            m.on_injector_pop();
            return (Some(job), false);
        }
        let n = self.stealers.len();
        let mut saw_retry = false;
        if n > 1 {
            let start = rng.next_below(n);
            for k in 0..n {
                let victim = (start + k) % n;
                if victim == index {
                    continue;
                }
                match self.stealers[victim].steal() {
                    Steal::Success(job) => {
                        m.on_steal();
                        return (Some(job), saw_retry);
                    }
                    Steal::Retry => {
                        m.on_steal_failure();
                        saw_retry = true;
                    }
                    Steal::Empty => {}
                }
            }
        }
        (None, saw_retry)
    }

    /// True if any work might be available (used to re-check before
    /// parking; conservative — may say true spuriously).
    fn any_work(&self) -> bool {
        !self.injector.is_empty() || self.stealers.iter().any(|s| !s.is_empty())
    }

    /// Executes one job, containing panics. (Executed counts are
    /// derived from pop/steal/injector counters — see metrics.rs.)
    pub(crate) fn run_job(self: &Arc<Self>, index: usize, job: Job) {
        match job {
            Job::Closure(f) => {
                if catch_unwind(AssertUnwindSafe(f)).is_err() {
                    self.panics.fetch_add(1, Ordering::Relaxed);
                }
            }
            Job::Node(run) => crate::graph::execute_node(self, index, run),
        }
        self.finish_job();
    }
}

fn worker_loop(inner: Arc<PoolInner>, index: usize, queue: Worker<Job>) {
    LOCAL.with(|l| {
        l.set(Some(LocalWorker {
            pool: Arc::as_ptr(&inner),
            queue: &queue as *const Worker<Job>,
            index,
        }))
    });
    let _guard = LocalGuard;
    let mut rng = XorShift64Star::from_entropy();

    'outer: loop {
        // Work until dry, spinning through `spin_rounds` extra sweeps.
        let mut spins = 0;
        loop {
            let (job, saw_retry) = inner.find_task(index, &queue, &mut rng);
            match job {
                Some(job) => {
                    inner.run_job(index, job);
                    spins = 0;
                }
                None if saw_retry => {
                    // Someone is mid-operation on a victim deque;
                    // back off a touch and retry without parking.
                    std::hint::spin_loop();
                }
                None => {
                    spins += 1;
                    if spins > inner.spin_rounds {
                        break;
                    }
                    std::thread::yield_now();
                }
            }
        }

        // Park protocol: register as sleeper, re-check, sleep.
        let token = inner.ec.prepare_wait();
        if inner.shutdown.load(Ordering::SeqCst) {
            inner.ec.cancel_wait(token);
            // Drain remaining work before exiting so drop() does not
            // strand submitted tasks.
            while let (Some(job), _) = inner.find_task(index, &queue, &mut rng) {
                inner.run_job(index, job);
            }
            break 'outer;
        }
        if inner.any_work() {
            inner.ec.cancel_wait(token);
            continue;
        }
        inner.metrics[index].on_park();
        inner.ec.commit_wait(token);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::time::Duration;

    #[test]
    fn executes_submitted_tasks() {
        let pool = ThreadPool::new(2);
        let count = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let count = count.clone();
            pool.submit(move || {
                count.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_idle();
        assert_eq!(count.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn zero_threads_clamps_to_one() {
        let pool = ThreadPool::new(0);
        assert_eq!(pool.num_threads(), 1);
        let hit = Arc::new(AtomicUsize::new(0));
        let h = hit.clone();
        pool.submit(move || {
            h.fetch_add(1, Ordering::Relaxed);
        });
        pool.wait_idle();
        assert_eq!(hit.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn tasks_submitting_tasks() {
        // Recursive fan-out: each task spawns children; wait_idle must
        // cover transitively spawned work.
        let pool = Arc::new(ThreadPool::new(3));
        let count = Arc::new(AtomicUsize::new(0));
        fn spawn(pool: &Arc<ThreadPool>, count: &Arc<AtomicUsize>, depth: usize) {
            count.fetch_add(1, Ordering::Relaxed);
            if depth == 0 {
                return;
            }
            for _ in 0..2 {
                let (p, c) = (pool.clone(), count.clone());
                pool.submit(move || spawn(&p, &c, depth - 1));
            }
        }
        spawn(&pool, &count, 0); // count the root call manually
        let (p, c) = (pool.clone(), count.clone());
        pool.submit(move || spawn(&p, &c, 9));
        pool.wait_idle();
        // Root manual call (1) + full binary tree of depth 9 (2^10 - 1).
        assert_eq!(count.load(Ordering::Relaxed), 1 + (1 << 10) - 1);
    }

    #[test]
    fn worker_submit_uses_local_queue() {
        let pool = ThreadPool::new(1);
        let pushed = Arc::new(AtomicUsize::new(0));
        let p = pushed.clone();
        pool.submit(move || {
            p.fetch_add(1, Ordering::Relaxed);
        });
        pool.wait_idle();
        // Now submit from inside a task and check the metrics counted a
        // local push.
        let inner_done = Arc::new(AtomicUsize::new(0));
        let d = inner_done.clone();
        struct PoolPtr(*const ThreadPool);
        unsafe impl Send for PoolPtr {}
        let pp = PoolPtr(&pool as *const ThreadPool);
        pool.submit(move || {
            // Capture the whole wrapper (edition-2021 closures would
            // otherwise capture only the raw-pointer field).
            let pp = pp;
            // SAFETY: `pool` outlives this task; wait_idle below joins it.
            let pool = unsafe { &*pp.0 };
            let d2 = d.clone();
            pool.submit(move || {
                d2.fetch_add(1, Ordering::Relaxed);
            });
        });
        pool.wait_idle();
        assert_eq!(inner_done.load(Ordering::Relaxed), 1);
        assert!(pool.metrics().total().pushes >= 1, "inner submit should hit the local deque");
    }

    #[test]
    fn panicking_task_is_contained() {
        let pool = ThreadPool::new(2);
        pool.submit(|| panic!("boom"));
        let ok = Arc::new(AtomicUsize::new(0));
        let o = ok.clone();
        pool.submit(move || {
            o.fetch_add(1, Ordering::Relaxed);
        });
        pool.wait_idle();
        assert_eq!(pool.panic_count(), 1);
        assert_eq!(ok.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn drop_drains_submitted_work() {
        let count = Arc::new(AtomicUsize::new(0));
        {
            let pool = ThreadPool::new(2);
            for _ in 0..50 {
                let count = count.clone();
                pool.submit(move || {
                    std::thread::sleep(Duration::from_micros(100));
                    count.fetch_add(1, Ordering::Relaxed);
                });
            }
            // Drop without wait_idle.
        }
        assert_eq!(count.load(Ordering::Relaxed), 50);
    }

    #[test]
    fn wait_idle_on_idle_pool_returns_immediately() {
        let pool = ThreadPool::new(2);
        pool.wait_idle();
        pool.wait_idle();
    }

    #[test]
    fn current_worker_identity() {
        let pool = Arc::new(ThreadPool::new(2));
        assert_eq!(pool.current_worker(), None);
        let p = pool.clone();
        let (tx, rx) = std::sync::mpsc::channel();
        pool.submit(move || {
            tx.send(p.current_worker()).unwrap();
        });
        let idx = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(matches!(idx, Some(i) if i < 2));
        pool.wait_idle();
    }

    #[test]
    fn lock_free_injector_config() {
        let pool = ThreadPool::with_config(PoolConfig {
            num_threads: 2,
            injector: InjectorKind::LockFree,
            ..PoolConfig::default()
        });
        let count = Arc::new(AtomicUsize::new(0));
        for _ in 0..500 {
            let count = count.clone();
            pool.submit(move || {
                count.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_idle();
        assert_eq!(count.load(Ordering::Relaxed), 500);
    }

    #[test]
    fn many_waves_of_work_with_parking_between() {
        let pool = ThreadPool::new(2);
        let count = Arc::new(AtomicUsize::new(0));
        for wave in 0..20 {
            for _ in 0..10 {
                let count = count.clone();
                pool.submit(move || {
                    count.fetch_add(1, Ordering::Relaxed);
                });
            }
            pool.wait_idle();
            assert_eq!(count.load(Ordering::Relaxed), (wave + 1) * 10);
            // Let workers park so the next wave exercises wakeup.
            std::thread::sleep(Duration::from_millis(2));
        }
    }
}
