//! The work-stealing thread pool (paper §2.1, §4.1).
//!
//! Architecture, mirroring the paper:
//!
//! * one Chase–Lev deque per worker ([`super::deque`], the fence-free
//!   variant);
//! * an injector per worker **shard** for submissions from non-worker
//!   threads (one global injector in the paper; sharded since PR 5 —
//!   see below);
//! * **thread-local worker registration**: instead of a map from thread
//!   id to queue index (the "typical approach" the paper calls out), a
//!   `thread_local!` slot identifies the current worker and its deque,
//!   so `submit` from inside a task pushes straight to the local deque
//!   with no lookup;
//! * an eventcount so idle workers sleep instead of spinning (this is
//!   what keeps Fig. 2's CPU-time curve close to wall-time × threads).
//!
//! Workers run: pop own deque → steal (injector + random-start sweep
//! over victims) → park. On shutdown the pool drains remaining work
//! before joining.
//!
//! # Hot-path design (PR 1)
//!
//! Three optimizations, each independently toggleable via
//! [`PoolConfig`] for the `ablations` bench:
//!
//! 1. **Inline task storage** ([`PoolConfig::inline_tasks`]) — tasks
//!    are [`RawTask`] cells: closures up to 3 words live inline, no
//!    heap allocation from submit to execute (see [`super::task`]).
//! 2. **Batched stealing** ([`PoolConfig::steal_batch`]) — a thief
//!    that finds a loaded victim takes up to half its run in one
//!    visit ([`Stealer::steal_batch_and_pop`]), then works locally
//!    instead of re-entering the steal sweep per task.
//! 3. **Throttled, batched wakeups** ([`PoolConfig::batched_wakeups`])
//!    — a burst of N ready tasks (graph fan-out, source submission)
//!    is published with one shared-counter bump and one wake instead
//!    of N of each; per-submit notifies remain O(1) loads when no
//!    worker is parked.
//!
//! The seed's single SeqCst `pending` counter — one contended RMW on
//! every submit *and* every completion — is replaced by per-worker
//! cache-padded `(submitted, completed)` cells (single-writer each)
//! plus one external-submitter cell. [`ThreadPool::wait_idle`] detects
//! quiescence with a two-pass scan (all `completed`, then all
//! `submitted`; equal sums ⇒ idle): any job whose completion the
//! first pass counted had its submission counted by the second, so
//! the test cannot report idle while work is in flight.
//!
//! # Sharded submission & locality-aware stealing (PR 5)
//!
//! Workers are grouped into **shards** ([`super::topology`]): each
//! shard owns its own [`LaneInjector`] and its own [`EventCount`], so
//! external submission storms fan out over `num_shards` queues instead
//! of serializing on one CAS/mutex line, and sleep/wake traffic stays
//! inside a cache-sharing neighbourhood.
//!
//! * **Submission routing** — a worker pushes to its own deque
//!   (unchanged); a caller-assist helper pushes to the home shard it
//!   was assigned on entry; any other external thread round-robins
//!   over shards through a *striped* (thread-local) cursor, so two
//!   producer threads never contend on a routing counter either. A
//!   graph run can pin its cross-thread submissions to one shard
//!   (`graph::RunOptions::shard`), and [`ThreadPool::submit_to_shard`]
//!   pins a single task.
//! * **Two-level idle sweep** — own deque → home-shard injector →
//!   same-shard victim deques (batched steal) → remote shards
//!   (injector, then deques, random start). Locality is preferred but
//!   every queue of every shard is visited before a worker gives up,
//!   so cross-shard starvation is impossible; the sweep-order and
//!   starvation tests in `rust/tests/pool_sharding.rs` pin both
//!   properties down.
//! * **Park protocol** — a worker parks on its *shard's* eventcount,
//!   but only after re-checking **all** shards' injectors and deques
//!   ([`PoolInner::any_work`]); producers wake a home-shard sleeper
//!   first and fall through to any shard with a sleeper. The
//!   two-level re-check/wake handshake is loom-modeled in
//!   `rust/tests/loom_model.rs`, and multi-shard parks keep a timeout
//!   backstop so liveness never rests on the model alone.
//!
//! A pool with a single shard (any pool where
//! `shard_size >= num_threads`, including every small pool under the
//! auto setting) routes through exactly the pre-PR 5 code: one
//! injector, one eventcount, a flat victim sweep, unbounded parks.
//! `ABL-8` in `benches/ablations.rs` measures flat vs. sharded under
//! a many-producer storm.
//!
//! # Run-lifecycle robustness (PR 6)
//!
//! Two pool-side additions back the graph layer's lifecycle work:
//!
//! * **Admission control** — [`PoolConfig::max_inflight_runs`] and
//!   [`PoolConfig::max_queued_tasks`] bound how many graph runs may be
//!   in flight and how much queued work a new run may pile on. The
//!   graph executor calls [`PoolInner::admit_run`] before launching:
//!   `try_run` fails fast with `GraphError::Overloaded`, blocking
//!   `run` parks on a dedicated budget eventcount until a slot frees,
//!   and Low-class runs (PR 4) are shed first — they see a reduced
//!   effective limit and never block. Both knobs default to `0`
//!   (unlimited), in which case admission is a single branch and the
//!   pool behaves exactly as before PR 6.
//! * **Panic quarantine & worker revival** — closure panics are
//!   contained in the task vtable and graph-node panics inside
//!   `graph::execute_node`, so nothing unwinds into the worker loop by
//!   construction. Defense-in-depth for the day that invariant breaks:
//!   [`PoolInner::run_job`] completes its counter bump through a drop
//!   guard (an escaped unwind can no longer unbalance the quiescence
//!   scan and hang `wait_idle`), and the worker loop catches any
//!   escaped unwind, records it (`PoolSnapshot::worker_revivals`), and
//!   **revives in place** — deque and TLS registration live in the
//!   same frame, so the worker re-enters its sweep with identity
//!   intact and the pool never silently shrinks.
//!   `PoolSnapshot::alive_workers` reports the live count.

use std::cell::Cell;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, Weak};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::timer;

use super::deque::{deque, Steal, Stealer, Worker};
use super::event_count::EventCount;
use super::injector::{Injector, LaneInjector, MutexInjector, SegQueue, DEFAULT_LANE, NUM_LANES};
use super::metrics::{PaddedMetrics, PoolSnapshot, ShardSnapshot, WorkerMetrics};
use super::task::RawTask;
use super::topology::PoolTopology;
use crate::obs::{EventKind, FlightDump, FlightRecorder, Histogram, HistogramSnapshot};
use crate::util::{CachePadded, XorShift64Star};

/// Timeout backstop for multi-shard worker parks: with per-shard
/// eventcounts, the producer-side wakeup targeting crosses eventcount
/// instances (notify the home shard's sleeper first, fall through to
/// any shard with one). That protocol is loom-modeled, but unlike the
/// single-eventcount case it is not the decade-old textbook argument,
/// so multi-shard parks re-check their work sources at this cadence
/// regardless — one spurious sweep per parked worker per period, which
/// keeps Fig. 2's CPU-time story intact while making liveness
/// unconditional. Flat (single-shard) pools park unbounded, exactly
/// as before PR 5.
const SHARD_PARK_BACKSTOP: Duration = Duration::from_millis(100);

/// Which injector implementation backs external submissions.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum InjectorKind {
    /// `Mutex<VecDeque>` — default; injector is off the hot path.
    #[default]
    Mutex,
    /// Lock-free segmented queue — for injector-heavy workloads.
    LockFree,
}

/// Pool construction parameters.
#[derive(Debug, Clone)]
pub struct PoolConfig {
    /// Worker thread count. Defaults to
    /// `std::thread::available_parallelism()`.
    pub num_threads: usize,
    /// How many full find-task sweeps a worker performs before parking.
    /// Higher values trade CPU time (Fig. 2) for wakeup latency.
    pub spin_rounds: u32,
    /// Injector implementation.
    pub injector: InjectorKind,
    /// Name prefix for worker threads (shows up in profilers).
    pub thread_name: String,
    /// Store small closures inline in the task cell instead of boxing
    /// every task (hot-path optimization 1; `false` reproduces the
    /// seed's `Box<dyn FnOnce>`-per-task behaviour for ablations).
    pub inline_tasks: bool,
    /// Steal up to half of a victim's run per visit instead of one
    /// task at a time (hot-path optimization 2).
    pub steal_batch: bool,
    /// Publish bursts of ready tasks with a single counter bump and a
    /// single wake instead of per-task submission (hot-path
    /// optimization 3; applies to graph fan-out and source submission).
    pub batched_wakeups: bool,
    /// Workers per shard (PR 5): each shard owns its own injector and
    /// eventcount, and the idle sweep prefers same-shard work. `0`
    /// (the default) derives the size from the worker count —
    /// shards of up to [`super::topology::DEFAULT_SHARD_WORKERS`]
    /// workers, so small pools stay flat. Any value
    /// `>= num_threads` forces a single shard: the flat, pre-PR 5
    /// pool (the ABL-8 comparison arm).
    pub shard_size: usize,
    /// Maximum graph runs in flight at once (PR 6). `0` (the default)
    /// means unlimited — admission is then a single branch. When set,
    /// `try_run` beyond the limit returns `GraphError::Overloaded`,
    /// blocking `run` waits on the budget eventcount, and Low-class
    /// runs see a reduced effective limit (shed first, never block).
    pub max_inflight_runs: usize,
    /// Maximum tasks that may be queued (pending estimate) for a new
    /// run to be admitted (PR 6). `0` (the default) means unlimited.
    /// Checked together with `max_inflight_runs` at admission time;
    /// the estimate is the same relaxed snapshot as
    /// [`ThreadPool::pending`], which is exactly the right tool for a
    /// backpressure heuristic (precise counting would put a shared RMW
    /// back on the submit path sharding just removed).
    pub max_queued_tasks: usize,
    /// Keep the flight recorder on (PR 9): per-worker lock-free ring
    /// buffers of scheduler events (task start/end, steal, park/wake,
    /// admission verdicts, aborts, brownout transitions), dumpable via
    /// [`ThreadPool::flight_dump`] and automatically on run failures.
    /// Recording is a few ns per event with zero allocation; the
    /// ABL-9 ablation arm measures the cost. Default on.
    pub flight_recorder: bool,
    /// Events retained per flight-recorder lane (rounded up to a power
    /// of two); older events are overwritten — see
    /// [`crate::obs::flight`] for the exact semantics.
    pub flight_capacity: usize,
    /// Keep the pool-level histograms on (PR 9): log-bucketed atomic
    /// series for dispatch queue delay and node duration, plus the
    /// per-node run-profile timestamps behind
    /// `RunHandle::profile()`. Default on.
    pub histograms: bool,
}

impl Default for PoolConfig {
    fn default() -> Self {
        Self {
            num_threads: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            spin_rounds: 2,
            injector: InjectorKind::default(),
            thread_name: "scheduling-worker".to_string(),
            inline_tasks: true,
            steal_batch: true,
            batched_wakeups: true,
            shard_size: 0,
            max_inflight_runs: 0,
            max_queued_tasks: 0,
            flight_recorder: true,
            flight_capacity: 4096,
            histograms: true,
        }
    }
}

/// Pool-level histogram series (PR 9), allocated once at pool
/// construction when [`PoolConfig::histograms`] is on.
pub(crate) struct PoolHists {
    /// Dispatch-queue delay (same samples as the EWMA).
    pub(crate) queue_delay: Histogram,
    /// Per-node execution duration across all graph runs.
    pub(crate) node_duration: Histogram,
}

/// Thread-local identity of a worker: which pool it belongs to and a
/// pointer to its own deque. This is the paper's "thread-local variable
/// instead of a thread-id → queue-index map" (§2.1).
#[derive(Clone, Copy)]
struct LocalWorker {
    pool: *const PoolInner,
    queue: *const Worker<RawTask>,
    index: usize,
}

thread_local! {
    static LOCAL: Cell<Option<LocalWorker>> = const { Cell::new(None) };
    /// Pool this thread is currently *assisting* (a caller-assist
    /// graph run executing tasks on the submitting thread); null when
    /// not assisting. Lets the graph executor reject nested
    /// `TaskGraph::run` calls on the same pool deterministically — the
    /// same task must error whether a worker or a helper picked it up.
    static ASSISTING: Cell<*const ()> = const { Cell::new(std::ptr::null()) };
    /// Home shard of the current assist scope (PR 5): assigned on
    /// entry (`AssistGuard::enter`), it is where the helper's
    /// submissions land and where it parks. Only meaningful while
    /// `ASSISTING` matches the pool being asked.
    static ASSIST_SHARD: Cell<usize> = const { Cell::new(0) };
    /// Striped round-robin cursors for external submissions (PR 5):
    /// per-thread AND per-pool (keyed by `PoolInner` address — a tiny
    /// linear-scan vec, since a thread rarely feeds more than a couple
    /// of pools), so spreading a submission storm over the shards
    /// costs zero shared RMWs — the very contention sharding removes
    /// must not sneak back in through the router. Per-pool keying
    /// matters: one shared counter would let interleaved submissions
    /// to two pools alias (e.g. two 2-shard pools fed alternately
    /// would each see a constant cursor parity and re-concentrate on
    /// one shard). A reused allocation address after a pool drop can
    /// at worst inherit a stale cursor value, which only shifts the
    /// round-robin phase.
    static STRIPE: std::cell::RefCell<Vec<(*const (), usize)>> =
        const { std::cell::RefCell::new(Vec::new()) };
}

/// Seed source for [`STRIPE`]: one global bump per (thread, pool)
/// *first touch* (cold), staggering the cursors' round-robin phases so
/// simultaneous storms do not all start hammering shard 0.
static STRIPE_SEED: AtomicUsize = AtomicUsize::new(0);

/// Clears the TLS registration even if the worker loop unwinds.
struct LocalGuard;

impl Drop for LocalGuard {
    fn drop(&mut self) {
        LOCAL.with(|l| l.set(None));
    }
}

/// Marks the current thread as assisting `pool` for the guard's
/// lifetime, restoring the previous value on drop (assist scopes for
/// different pools can nest: a helper-executed task may legitimately
/// run a graph on a *different* pool). The guard also assigns the
/// helper its **home shard** (PR 5) — round-robin via the striped
/// cursor, so consecutive assist scopes spread over the shards — which
/// is where the helper submits, pops first, and parks.
struct AssistGuard {
    prev: *const (),
    prev_shard: usize,
}

impl AssistGuard {
    fn enter(pool: &PoolInner) -> Self {
        let ptr = pool as *const PoolInner as *const ();
        let shard = pool.striped_shard();
        AssistGuard {
            prev: ASSISTING.with(|a| a.replace(ptr)),
            prev_shard: ASSIST_SHARD.with(|s| s.replace(shard)),
        }
    }
}

impl Drop for AssistGuard {
    fn drop(&mut self) {
        let prev = self.prev;
        ASSISTING.with(|a| a.set(prev));
        let prev_shard = self.prev_shard;
        ASSIST_SHARD.with(|s| s.set(prev_shard));
    }
}

/// One shard of the distributed pending-work counter. Monotone
/// counters (never decremented) are what make the two-pass quiescence
/// scan sound — see the module docs.
///
/// Writer discipline: cell `i < n` is written only by worker `i`
/// (submissions it makes, completions it executes), so the hot path
/// never contends on a shared line; cell `n` takes submissions from
/// non-worker threads (plus the explicitly shard-pinned
/// [`ThreadPool::submit_to_shard`], wherever it is called from) and
/// completions from caller-assist helper threads (`run_helper_job`) —
/// all off the worker hot path.
#[derive(Default)]
struct PendingCell {
    submitted: AtomicU64,
    completed: AtomicU64,
}

/// One shard's scheduling state (PR 5): its injection queue and its
/// sleep/wake domain. A flat pool holds exactly one of these, and the
/// code that indexes `shards[0]` is then the pre-PR 5 single-injector,
/// single-eventcount pool verbatim.
struct ShardState {
    /// The shard's injection queue, split into [`NUM_LANES`] priority
    /// lanes (PR 4): untagged submissions use [`DEFAULT_LANE`]; graph
    /// runs with priority lanes enabled spread tasks by run class ×
    /// node rank (`graph::schedule::lane_compose`). Consumers pop
    /// most-urgent-first with a starvation-bounding reverse scan.
    injector: LaneInjector<RawTask>,
    /// Eventcount the shard's workers (and assist helpers homed here)
    /// park on. Producers prefer waking a home-shard sleeper and fall
    /// through to other shards' sleepers ([`PoolInner::notify_shard`]).
    ec: EventCount,
}

pub(crate) struct PoolInner {
    /// Per-shard injectors + eventcounts; `topology` maps workers to
    /// entries. Length 1 = the flat pre-PR 5 pool.
    shards: Box<[ShardState]>,
    /// Worker → shard arithmetic (immutable).
    topology: PoolTopology,
    stealers: Vec<Stealer<RawTask>>,
    metrics: Vec<PaddedMetrics>,
    /// Dedicated eventcount for threads blocked on a graph-run
    /// completion ([`PoolInner::wait_run`]). Separate from the shards'
    /// eventcounts on purpose: run waiters do not take work, so
    /// letting them park where workers park would let a work-arrival
    /// `notify_one` land on a waiter that just re-parks — with the
    /// task stranded and the worker it was meant for still asleep.
    /// Only run completions notify this one.
    run_ec: EventCount,
    /// `num_threads + 1` cells; see [`PendingCell`].
    counters: Vec<CachePadded<PendingCell>>,
    /// Tasks whose closure panicked (panics are contained per-job).
    panics: AtomicU64,
    shutdown: AtomicBool,
    /// Threads currently blocked in `wait_idle` (gates the completion-
    /// side wakeup check so the common case pays one load).
    idle_waiters: AtomicUsize,
    idle_mutex: Mutex<()>,
    idle_cv: Condvar,
    spin_rounds: u32,
    inline_tasks: bool,
    steal_batch: bool,
    batched_wakeups: bool,
    /// Admission limits (PR 6); 0 = unlimited. See [`PoolConfig`].
    max_inflight_runs: usize,
    max_queued_tasks: usize,
    /// Graph runs currently holding an admission slot. Only counted
    /// when `max_inflight_runs > 0` — the unlimited default never
    /// touches this cell.
    inflight_runs: AtomicUsize,
    /// Eventcount blocking `run` callers park on when the budget is
    /// exhausted; every released slot broadcasts here. Separate from
    /// the shard eventcounts for the same reason `run_ec` is: budget
    /// waiters take no work, so a work-arrival wakeup must never land
    /// on one.
    budget_ec: EventCount,
    /// Low-class runs rejected by admission (shed-first policy).
    shed_runs: AtomicU64,
    /// Dispatch-queue-delay EWMA in nanoseconds (PR 7): how long a run
    /// waited between arriving at a serving front-end and being
    /// dispatched to the pool. Fed by [`ThreadPool::note_queue_delay`]
    /// (the `serve::GraphService` gate reports every grant); consumed
    /// by the deadline-infeasibility check at the admission seam and by
    /// the serving tier's brownout controller. α = 1/8, relaxed
    /// read-modify-write — a racy lost update just weights one sample
    /// differently, which a load-level signal tolerates.
    queue_delay_ewma_ns: AtomicU64,
    /// Workers currently inside `worker_loop` (PR 6): incremented at
    /// entry, decremented at exit. `metrics()` reports it so tests can
    /// assert the pool never silently shrinks after a panic.
    alive_workers: AtomicUsize,
    /// Times a worker caught an unwind that escaped task containment
    /// and revived in place (PR 6). Zero in any correct build — the
    /// vtable and `execute_node` contain all panics — so a nonzero
    /// value is a loud signal that containment regressed.
    worker_revivals: AtomicU64,
    /// Timestamp epoch for the observability layer (PR 9): flight
    /// events and run-profile spans are nanoseconds since this
    /// instant, so the two can be cross-referenced on one timeline.
    epoch: Instant,
    /// Flight recorder (PR 9); `None` when disabled by config. Behind
    /// `Arc` so serve-layer components (brownout controller, retry
    /// scheduler) can hold a handle and record into the external lane.
    flight: Option<Arc<FlightRecorder>>,
    /// Pool-level histograms (PR 9); `None` when disabled by config.
    hists: Option<PoolHists>,
    /// The most recent automatic flight dump (PR 9): stashed by the
    /// executor when a run fails with `NodePanicked` or
    /// `DeadlineExceeded`, retrievable via
    /// [`ThreadPool::last_flight_dump`] for post-mortems.
    last_dump: Mutex<Option<FlightDump>>,
}

/// The work-stealing thread pool (see module docs).
///
/// Dropping the pool drains already-submitted work, then joins the
/// workers. Use [`ThreadPool::wait_idle`] to block until all submitted
/// work (including work spawned by work) has finished.
pub struct ThreadPool {
    inner: Arc<PoolInner>,
    threads: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// Creates a pool with `num_threads` workers (0 is clamped to 1).
    pub fn new(num_threads: usize) -> Self {
        Self::with_config(PoolConfig {
            num_threads,
            ..PoolConfig::default()
        })
    }

    /// Creates a pool with `available_parallelism()` workers, like the
    /// paper's default constructor.
    pub fn with_default_threads() -> Self {
        Self::with_config(PoolConfig::default())
    }

    /// Creates a pool from a full [`PoolConfig`].
    pub fn with_config(config: PoolConfig) -> Self {
        let n = config.num_threads.max(1);
        let epoch = Instant::now();
        let mut owners = Vec::with_capacity(n);
        let mut stealers = Vec::with_capacity(n);
        for _ in 0..n {
            let (w, s) = deque::<RawTask>(256);
            owners.push(w);
            stealers.push(s);
        }
        let kind = config.injector;
        let mk_injector = move || -> Box<dyn Injector<RawTask>> {
            match kind {
                InjectorKind::Mutex => Box::new(MutexInjector::new()),
                InjectorKind::LockFree => Box::new(SegQueue::new()),
            }
        };
        let topology = PoolTopology::new(n, config.shard_size);
        let shards: Box<[ShardState]> = (0..topology.num_shards())
            .map(|_| ShardState {
                injector: LaneInjector::new(mk_injector),
                ec: EventCount::new(),
            })
            .collect();
        let inner = Arc::new(PoolInner {
            shards,
            topology,
            stealers,
            // `n + 1` blocks: one per worker plus the shared helper
            // lane used by caller-assist threads (graph runs executing
            // tasks on the submitting thread) — see helper_lane().
            metrics: (0..n + 1).map(|_| PaddedMetrics::new(WorkerMetrics::default())).collect(),
            run_ec: EventCount::new(),
            counters: (0..n + 1).map(|_| CachePadded::new(PendingCell::default())).collect(),
            panics: AtomicU64::new(0),
            shutdown: AtomicBool::new(false),
            idle_waiters: AtomicUsize::new(0),
            idle_mutex: Mutex::new(()),
            idle_cv: Condvar::new(),
            spin_rounds: config.spin_rounds,
            inline_tasks: config.inline_tasks,
            steal_batch: config.steal_batch,
            batched_wakeups: config.batched_wakeups,
            max_inflight_runs: config.max_inflight_runs,
            max_queued_tasks: config.max_queued_tasks,
            inflight_runs: AtomicUsize::new(0),
            budget_ec: EventCount::new(),
            shed_runs: AtomicU64::new(0),
            queue_delay_ewma_ns: AtomicU64::new(0),
            alive_workers: AtomicUsize::new(0),
            worker_revivals: AtomicU64::new(0),
            epoch,
            // `n + 1` single-writer lanes (workers + the caller-assist
            // helper lane, mirroring `metrics`) plus the recorder's own
            // shared external lane for non-worker threads.
            flight: config
                .flight_recorder
                .then(|| Arc::new(FlightRecorder::new(n + 1, config.flight_capacity.max(2), epoch))),
            hists: config.histograms.then(|| PoolHists {
                queue_delay: Histogram::new(),
                node_duration: Histogram::new(),
            }),
            last_dump: Mutex::new(None),
        });
        let threads = owners
            .into_iter()
            .enumerate()
            .map(|(index, queue)| {
                let inner = inner.clone();
                std::thread::Builder::new()
                    .name(format!("{}-{index}", config.thread_name))
                    .spawn(move || worker_loop(inner, index, queue))
                    .expect("failed to spawn worker thread")
            })
            .collect();
        ThreadPool { inner, threads }
    }

    /// Submits a task — a function taking no arguments and returning
    /// nothing (paper §4.1); use captures for inputs/outputs. If called
    /// from a worker of *this* pool, pushes to that worker's own deque
    /// (no lock, no map lookup); otherwise goes through the injector.
    /// Closures capturing up to 3 words are stored without any heap
    /// allocation (see [`super::task`]).
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        let job = if self.inner.inline_tasks {
            RawTask::closure(f)
        } else {
            RawTask::boxed_closure(f)
        };
        self.inner.submit_job(job);
    }

    /// Blocks until every submitted job (and every job those jobs
    /// submitted, transitively) has finished.
    ///
    /// Must be called from outside the pool's tasks; calling it from
    /// inside a task of this pool — whether that task is executing on
    /// a worker thread or on a caller-assist helper — would deadlock
    /// (the calling task's own completion is never counted while it
    /// blocks) and panics in debug builds.
    pub fn wait_idle(&self) {
        debug_assert!(
            !self.inner.on_worker_thread() && !self.inner.on_assisting_thread(),
            "wait_idle called from inside a task of the same pool"
        );
        let inner = &*self.inner;
        if inner.quiescent() {
            return;
        }
        inner.idle_waiters.fetch_add(1, Ordering::SeqCst);
        let mut guard = inner.idle_mutex.lock().unwrap();
        while !inner.quiescent() {
            // Completions nudge the condvar at quiescence edges, but
            // that edge check is heuristic (a stale injector emptiness
            // flag can suppress it), so never sleep unboundedly on it.
            let (g, _) = inner
                .idle_cv
                .wait_timeout(guard, Duration::from_millis(1))
                .unwrap();
            guard = g;
        }
        drop(guard);
        inner.idle_waiters.fetch_sub(1, Ordering::SeqCst);
    }

    /// Number of worker threads.
    pub fn num_threads(&self) -> usize {
        self.inner.stealers.len()
    }

    /// Estimate of jobs submitted but not yet finished.
    ///
    /// Relaxed-read semantics (like [`ThreadPool::panic_count`]): the
    /// value is a snapshot of sharded counters taken without
    /// synchronization, exact only while the pool is externally
    /// quiescent. Use [`ThreadPool::wait_idle`] to synchronize.
    pub fn pending(&self) -> usize {
        self.inner.pending_estimate()
    }

    /// Number of tasks that panicked (panics are contained per-task and
    /// counted rather than tearing down the worker). Relaxed-read
    /// semantics, consistent with [`ThreadPool::pending`].
    pub fn panic_count(&self) -> u64 {
        self.inner.panics.load(Ordering::Relaxed)
    }

    /// Snapshot of scheduler metrics across workers. The last entry is
    /// the shared **helper lane**: work executed by caller-assist
    /// threads (graph runs helping from the submitting thread) rather
    /// than by a pool worker. `shards` carries the per-shard queue
    /// depths (PR 5) — injector lanes, member deques, parked workers —
    /// so a storm benchmark can report shard imbalance
    /// ([`PoolSnapshot::shard_imbalance`]), not just throughput.
    pub fn metrics(&self) -> PoolSnapshot {
        let inner = &*self.inner;
        let shards = (0..inner.num_shards())
            .map(|s| {
                let members = inner.topology.members(s);
                let lane_depths = inner.shards[s].injector.lane_depths();
                ShardSnapshot {
                    workers: (members.start, members.end),
                    injector_depth: lane_depths.iter().sum(),
                    lane_depths,
                    deque_depth: members.map(|w| inner.stealers[w].len()).sum(),
                    parked: inner.shards[s].ec.waiter_count(),
                }
            })
            .collect();
        PoolSnapshot {
            workers: inner.metrics.iter().map(|m| m.snapshot()).collect(),
            shards,
            alive_workers: inner.alive_workers.load(Ordering::SeqCst),
            worker_revivals: inner.worker_revivals.load(Ordering::Relaxed),
            shed_runs: inner.shed_runs.load(Ordering::Relaxed),
            queue_delay_ewma_ns: inner.queue_delay_ewma_ns.load(Ordering::Relaxed),
        }
    }

    /// Reports one observed dispatch-queue delay — how long a run
    /// request waited between arriving at a front-end and being
    /// dispatched to this pool (PR 7). Feeds the pool's queue-delay
    /// EWMA, which backs [`ThreadPool::queue_delay_ewma`], the
    /// deadline-infeasibility check at the graph admission seam
    /// ([`crate::graph::GraphError::WouldMissDeadline`]), and the
    /// serving tier's brownout controller. `serve::GraphService`
    /// reports every grant automatically; call this directly only if
    /// you run your own front-end.
    pub fn note_queue_delay(&self, delay: Duration) {
        self.inner.observe_queue_delay(delay);
    }

    /// The pool's dispatch-queue-delay EWMA (α = 1/8) over every
    /// [`ThreadPool::note_queue_delay`] observation; zero until the
    /// first one. The serving tier's load signal (PR 7).
    pub fn queue_delay_ewma(&self) -> Duration {
        self.inner.queue_delay_ewma()
    }

    /// Snapshots the flight recorder (PR 9) — every lane's ring,
    /// decoded and time-sorted — or `None` when the recorder is
    /// disabled ([`PoolConfig::flight_recorder`]). Convert with
    /// [`crate::obs::FlightDump::to_chrome_trace`] for
    /// `chrome://tracing` / Perfetto.
    pub fn flight_dump(&self) -> Option<FlightDump> {
        self.inner.flight().map(|f| f.dump())
    }

    /// The most recent *automatic* flight dump (PR 9): the executor
    /// stashes one whenever a run fails with
    /// [`crate::graph::GraphError::NodePanicked`] or
    /// [`crate::graph::GraphError::DeadlineExceeded`], so the moments
    /// leading up to the failure survive ring overwrite. `None` until
    /// the first such failure (or with the recorder disabled).
    pub fn last_flight_dump(&self) -> Option<FlightDump> {
        self.inner.take_last_flight_dump()
    }

    /// Handle to the flight recorder (PR 9) for components that record
    /// their own events into the shared external lane (the serve
    /// layer's brownout and retry machinery does this); `None` when
    /// disabled.
    pub fn flight_recorder(&self) -> Option<Arc<FlightRecorder>> {
        self.inner.flight().cloned()
    }

    /// Snapshot of the dispatch-queue-delay histogram (PR 9) — the
    /// same samples as [`ThreadPool::queue_delay_ewma`], log-bucketed
    /// so tails are visible; `None` when histograms are disabled
    /// ([`PoolConfig::histograms`]).
    pub fn queue_delay_histogram(&self) -> Option<HistogramSnapshot> {
        self.inner.hists().map(|h| h.queue_delay.snapshot())
    }

    /// Snapshot of the node-duration histogram (PR 9): execution time
    /// of every graph node run on this pool; `None` when histograms
    /// are disabled.
    pub fn node_duration_histogram(&self) -> Option<HistogramSnapshot> {
        self.inner.hists().map(|h| h.node_duration.snapshot())
    }

    /// Number of shards the pool's workers are grouped into (PR 5);
    /// 1 = the flat pre-PR 5 pool. See [`PoolConfig::shard_size`].
    pub fn num_shards(&self) -> usize {
        self.inner.num_shards()
    }

    /// Submits a task pinned to `shard`'s injector (clamped to the
    /// valid range) — the per-task locality knob (PR 5): co-locate a
    /// producer's stream of tasks on one cache-sharing worker group
    /// instead of round-robining it across the pool. Unlike
    /// [`ThreadPool::submit`], this routes through the shard's
    /// injector even when called from a worker thread — the point is
    /// shard placement, not the caller's own deque. The task is still
    /// visible to every shard through the two-level sweep, so pinning
    /// can never strand work.
    pub fn submit_to_shard<F: FnOnce() + Send + 'static>(&self, shard: usize, f: F) {
        let job = if self.inner.inline_tasks {
            RawTask::closure(f)
        } else {
            RawTask::boxed_closure(f)
        };
        let inner = &*self.inner;
        let shard = shard.min(inner.num_shards() - 1);
        // External-cell counting keeps the quiescence scan balanced
        // (the cell is multi-writer by design; see PendingCell docs);
        // count-before-publish as everywhere.
        inner.counters[inner.external_cell()].submitted.fetch_add(1, Ordering::Release);
        inner.shards[shard].injector.push_to(DEFAULT_LANE, job);
        inner.notify_shard(shard);
    }

    /// Worker index of the current thread if it belongs to this pool.
    pub fn current_worker(&self) -> Option<usize> {
        LOCAL.with(|l| match l.get() {
            Some(lw) if lw.pool == Arc::as_ptr(&self.inner) => Some(lw.index),
            _ => None,
        })
    }

    pub(crate) fn inner(&self) -> &Arc<PoolInner> {
        &self.inner
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.inner.shutdown.store(true, Ordering::SeqCst);
        self.inner.notify_all_workers();
        for t in self.threads.drain(..) {
            // A worker that parked between the store and the notify is
            // still woken: prepare_wait/notify ordering is SeqCst (see
            // event_count.rs docs), and workers re-check `shutdown`
            // after every wakeup.
            let _ = t.join();
        }
    }
}

impl std::fmt::Debug for ThreadPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ThreadPool")
            .field("num_threads", &self.num_threads())
            .field("pending", &self.pending())
            .finish()
    }
}

impl PoolInner {
    /// Per-worker metrics blocks (for the graph executor's inline-
    /// continuation counter).
    pub(crate) fn metrics(&self) -> &[PaddedMetrics] {
        &self.metrics
    }

    /// Counts a contained closure panic (called from the task vtable).
    pub(crate) fn note_panic(&self) {
        self.panics.fetch_add(1, Ordering::Relaxed);
    }

    /// True if the current thread is a worker of this pool.
    pub(crate) fn on_worker_thread(&self) -> bool {
        LOCAL.with(|l| matches!(l.get(), Some(lw) if std::ptr::eq(lw.pool, self)))
    }

    /// Index of the counter cell for non-worker submitters.
    #[inline]
    fn external_cell(&self) -> usize {
        self.counters.len() - 1
    }

    /// Number of shards (≥ 1).
    #[inline]
    pub(crate) fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Next shard from this thread's striped round-robin cursor for
    /// *this pool* (PR 5): a thread-local per-pool counter seeded once
    /// from a global bump, so concurrent producers spread over the
    /// shards without sharing a routing counter and without aliasing
    /// across pools (see [`STRIPE`]). Flat pools skip the TLS
    /// entirely.
    fn striped_shard(&self) -> usize {
        let ns = self.num_shards();
        if ns == 1 {
            return 0;
        }
        let key = self as *const PoolInner as *const ();
        STRIPE.with(|s| {
            let mut cursors = s.borrow_mut();
            let cur = match cursors.iter_mut().find(|(k, _)| *k == key) {
                Some((_, cur)) => {
                    *cur = cur.wrapping_add(1);
                    *cur
                }
                None => {
                    let seed = STRIPE_SEED.fetch_add(1, Ordering::Relaxed);
                    cursors.push((key, seed));
                    seed
                }
            };
            cur % ns
        })
    }

    /// Resolves the target shard of an injector-bound submission:
    /// an explicit hint (clamped) wins; a caller-assist helper routes
    /// to its home shard; everything else round-robins through the
    /// striped cursor. Single-shard pools resolve to 0 without
    /// touching any of that — the flat fast path.
    fn route_shard(&self, hint: Option<usize>) -> usize {
        if self.num_shards() == 1 {
            return 0;
        }
        if let Some(shard) = hint {
            return shard.min(self.num_shards() - 1);
        }
        if self.on_assisting_thread() {
            return ASSIST_SHARD.with(|s| s.get()).min(self.num_shards() - 1);
        }
        self.striped_shard()
    }

    /// Home shard of the current thread for *consuming* work: a worker
    /// sweeps from its own shard, an assist helper from the shard it
    /// was assigned on entry, anything else from shard 0.
    fn current_home_shard(&self) -> usize {
        if let Some(lw) = LOCAL.with(|l| l.get()) {
            if std::ptr::eq(lw.pool, self) {
                return self.topology.shard_of(lw.index);
            }
        }
        if self.on_assisting_thread() {
            return ASSIST_SHARD.with(|s| s.get()).min(self.num_shards() - 1);
        }
        0
    }

    /// True when every shard's injector looks empty (same staleness
    /// caveats as [`Injector::is_empty`]).
    fn injectors_empty(&self) -> bool {
        self.shards.iter().all(|s| s.injector.is_empty())
    }

    /// Wakes one sleeper for work pushed toward `shard`, preferring a
    /// **home-shard** sleeper (it finds the task on the first probe of
    /// its sweep) and falling through to any shard with a sleeper —
    /// work must never idle behind a shard whose workers are all busy
    /// while another shard sleeps. If no shard has a registered
    /// sleeper this is `num_shards` SeqCst loads and no syscall; any
    /// sleeper registering after those loads re-checks **all** shards
    /// before committing its park ([`PoolInner::any_work`]), which is
    /// the same two-sided argument as the single-eventcount protocol
    /// (`event_count.rs` module docs), extended across eventcount
    /// instances — loom-modeled in `rust/tests/loom_model.rs` and
    /// backstopped by [`SHARD_PARK_BACKSTOP`].
    fn notify_shard(&self, shard: usize) {
        let ns = self.num_shards();
        if ns == 1 {
            // Flat pool: the pre-PR 5 notify, bit for bit.
            self.shards[0].ec.notify_one();
            return;
        }
        for k in 0..ns {
            let s = (shard + k) % ns;
            if self.shards[s].ec.waiter_count() > 0 {
                self.shards[s].ec.notify_one();
                return;
            }
        }
    }

    /// Burst flavour of [`PoolInner::notify_shard`]: `n > 1` tasks were
    /// published, so broadcast — the home shard's sleepers plus every
    /// other shard's (remote sleepers may be the only idle capacity,
    /// and excess wakeups just re-check and re-park, exactly as the
    /// pre-PR 5 `notify_all` behaved).
    fn notify_burst(&self, shard: usize, n: usize) {
        if n == 1 {
            self.notify_shard(shard);
            return;
        }
        let ns = self.num_shards();
        for k in 0..ns {
            self.shards[(shard + k) % ns].ec.notify_all();
        }
    }

    /// Schedules a job: local deque if on a worker of this pool,
    /// injector otherwise. The submitted-counter bump precedes the
    /// push so a job can never be findable (and completable) before
    /// it is counted — the quiescence scan depends on that order.
    pub(crate) fn submit_job(&self, job: RawTask) {
        self.submit_job_to(None, DEFAULT_LANE, job);
    }

    /// [`PoolInner::submit_job`] with an explicit injector lane (and,
    /// PR 5, an optional shard hint) for the cross-thread path. A
    /// worker's own deque has no lanes and no shard routing — both
    /// only matter when the task travels through an injector.
    pub(crate) fn submit_job_to(&self, hint: Option<usize>, lane: u8, job: RawTask) {
        let target = match LOCAL.with(|l| l.get()) {
            Some(lw) if std::ptr::eq(lw.pool, self) => {
                self.counters[lw.index].submitted.fetch_add(1, Ordering::Release);
                // SAFETY: `queue` points at the Worker owned by this
                // thread's worker_loop frame, which outlives any task
                // it executes; we are that task.
                unsafe { (*lw.queue).push(job) };
                self.metrics[lw.index].on_push();
                // Wake a neighbour first: it can steal with one probe.
                self.topology.shard_of(lw.index)
            }
            _ => {
                let shard = self.route_shard(hint);
                self.counters[self.external_cell()].submitted.fetch_add(1, Ordering::Release);
                self.shards[shard].injector.push_to(lane, job);
                shard
            }
        };
        // O(1) loads (no lock, no syscall) when nobody is parked.
        self.notify_shard(target);
    }

    /// Schedules a burst of jobs with one counter bump, one deque/
    /// injector push sequence, and one wake — the fan-out fast path
    /// (graph successors, source submission). Falls back to per-job
    /// [`PoolInner::submit_job`] when `batched_wakeups` is disabled.
    pub(crate) fn submit_job_batch<I>(&self, jobs: I)
    where
        I: ExactSizeIterator<Item = RawTask>,
    {
        self.submit_job_batch_sharded(None, jobs);
    }

    /// [`PoolInner::submit_job_batch`] with an optional shard hint
    /// (PR 5): the whole burst lands in one shard's injector, keeping
    /// its FIFO order intact and its consumers cache-local.
    pub(crate) fn submit_job_batch_sharded<I>(&self, hint: Option<usize>, jobs: I)
    where
        I: ExactSizeIterator<Item = RawTask>,
    {
        if !self.batched_wakeups {
            for job in jobs {
                self.submit_job_to(hint, DEFAULT_LANE, job);
            }
            return;
        }
        let n = jobs.len();
        if n == 0 {
            return;
        }
        let target = match LOCAL.with(|l| l.get()) {
            Some(lw) if std::ptr::eq(lw.pool, self) => {
                // Count before publishing (see submit_job).
                self.counters[lw.index].submitted.fetch_add(n as u64, Ordering::Release);
                for job in jobs {
                    // SAFETY: as in submit_job.
                    unsafe { (*lw.queue).push(job) };
                }
                self.metrics[lw.index].on_push_n(n as u64);
                self.topology.shard_of(lw.index)
            }
            _ => {
                let shard = self.route_shard(hint);
                self.counters[self.external_cell()].submitted.fetch_add(n as u64, Ordering::Release);
                let mut jobs = jobs;
                self.shards[shard].injector.push_batch_to(DEFAULT_LANE, &mut jobs);
                shard
            }
        };
        // One epoch bump + broadcast instead of n wakes for n > 1;
        // excess sleepers re-check their work sources and re-park.
        self.notify_burst(target, n);
    }

    /// Priority-aware burst submission for graph nodes (PR 4): the
    /// graph executor hands over the ready node indices plus two
    /// callbacks — `lane_for` (the composed injector lane of a node)
    /// and `mk` (node index → `RawTask`).
    ///
    /// `ranked` means `nodes` is sorted by **descending** critical-path
    /// rank, and the burst must reach consumers most-critical-first in
    /// every queue discipline:
    ///
    /// * worker-local deque (LIFO for its owner) — pushed in *reverse*,
    ///   so the owner pops in descending rank;
    /// * injector lanes (FIFO) — pushed in the given order, grouped
    ///   into contiguous per-lane batches (`lane_for` is monotone
    ///   non-decreasing along a rank-sorted burst, so grouping is one
    ///   forward walk).
    ///
    /// Unranked bursts keep their discovery order; per-lane grouping
    /// then takes one filtering pass per lane. Counter/wake discipline
    /// is identical to [`PoolInner::submit_job_batch`], including the
    /// per-task fallback when batched wakeups are disabled. The shard
    /// `hint` (PR 5) pins the cross-thread half of the burst to one
    /// shard's injector (`graph::RunOptions::shard`); worker-local
    /// pushes ignore it — the owner's deque *is* the locality optimum.
    pub(crate) fn submit_node_burst(
        &self,
        hint: Option<usize>,
        nodes: &[usize],
        ranked: bool,
        lane_for: &dyn Fn(usize) -> u8,
        mk: &dyn Fn(usize) -> RawTask,
    ) {
        let n = nodes.len();
        if n == 0 {
            return;
        }
        if !self.batched_wakeups {
            // Per-task submission (ablation arm). Keep the LIFO
            // compensation: on a worker, later pushes pop first.
            if ranked && self.on_worker_thread() {
                for &node in nodes.iter().rev() {
                    self.submit_job_to(hint, lane_for(node), mk(node));
                }
            } else {
                for &node in nodes {
                    self.submit_job_to(hint, lane_for(node), mk(node));
                }
            }
            return;
        }
        let target = match LOCAL.with(|l| l.get()) {
            Some(lw) if std::ptr::eq(lw.pool, self) => {
                // Count before publishing (see submit_job).
                self.counters[lw.index].submitted.fetch_add(n as u64, Ordering::Release);
                let push = |node: usize| {
                    // SAFETY: as in submit_job.
                    unsafe { (*lw.queue).push(mk(node)) };
                };
                if ranked {
                    nodes.iter().rev().for_each(|&node| push(node));
                } else {
                    nodes.iter().for_each(|&node| push(node));
                }
                self.metrics[lw.index].on_push_n(n as u64);
                self.topology.shard_of(lw.index)
            }
            _ => {
                let shard = self.route_shard(hint);
                let injector = &self.shards[shard].injector;
                self.counters[self.external_cell()].submitted.fetch_add(n as u64, Ordering::Release);
                if ranked {
                    // Contiguous per-lane runs of the rank-sorted burst.
                    let mut i = 0;
                    while i < n {
                        let lane = lane_for(nodes[i]);
                        let mut j = i + 1;
                        while j < n && lane_for(nodes[j]) == lane {
                            j += 1;
                        }
                        injector.push_batch_to(lane, &mut nodes[i..j].iter().map(|&node| mk(node)));
                        i = j;
                    }
                } else {
                    for lane in 0..NUM_LANES as u8 {
                        let mut it = nodes
                            .iter()
                            .filter(|&&node| lane_for(node) == lane)
                            .map(|&node| mk(node))
                            .peekable();
                        if it.peek().is_some() {
                            injector.push_batch_to(lane, &mut it);
                        }
                    }
                }
                shard
            }
        };
        self.notify_burst(target, n);
    }

    /// Called on the executing worker after a job finishes.
    fn finish_job(&self, index: usize) {
        self.counters[index].completed.fetch_add(1, Ordering::Release);
        // Cold path: only when a thread is blocked in wait_idle AND
        // this worker sees no remaining work nearby does it pay the
        // mutex for a precise wakeup. The waiter re-checks with the
        // authoritative two-pass scan (1 ms timeout backstop covers
        // the stale-emptiness-flag corner).
        if self.idle_waiters.load(Ordering::Acquire) != 0
            && self.stealers[index].is_empty()
            && self.injectors_empty()
        {
            // Lock/unlock pairs with the check-then-wait in wait_idle.
            drop(self.idle_mutex.lock().unwrap());
            self.idle_cv.notify_all();
        }
    }

    /// Two-pass quiescence test: sum all `completed`, then all
    /// `submitted`; equality means every job counted as submitted has
    /// also completed. Any completion the first pass observed had its
    /// submission observed by the second (submit-inc happens-before
    /// completion-inc happens-before our acquiring read), so the test
    /// never reports idle while transitively-spawned work is in
    /// flight. See the module docs for the full argument.
    fn quiescent(&self) -> bool {
        let mut completed = 0u64;
        for c in &self.counters {
            completed += c.completed.load(Ordering::Acquire);
        }
        let mut submitted = 0u64;
        for c in &self.counters {
            submitted += c.submitted.load(Ordering::Acquire);
        }
        submitted == completed
    }

    /// Relaxed snapshot of jobs submitted but not yet finished — the
    /// backing of [`ThreadPool::pending`] and the queue-pressure check
    /// in [`PoolInner::admit_run`]. Exact only while the pool is
    /// externally quiescent; good enough for a backpressure heuristic.
    pub(crate) fn pending_estimate(&self) -> usize {
        let mut completed = 0u64;
        for c in &self.counters {
            completed += c.completed.load(Ordering::Relaxed);
        }
        let mut submitted = 0u64;
        for c in &self.counters {
            submitted += c.submitted.load(Ordering::Relaxed);
        }
        submitted.saturating_sub(completed) as usize
    }

    /// One admission attempt (PR 6): takes an inflight slot if the
    /// budget allows. Callers that got `true` must pair it with
    /// exactly one [`PoolInner::release_run_slot`].
    ///
    /// Low-class runs see a reduced effective limit — at least one
    /// slot, but the top quarter of the budget is reserved for
    /// Normal/High runs, so under saturation Low is shed first
    /// (PR 4's run classes carried into overload policy).
    fn try_take_slot(&self, n_tasks: usize, low_class: bool) -> bool {
        let max = self.max_inflight_runs;
        if max > 0 {
            let limit = if low_class { (max - max / 4).max(1) } else { max };
            let mut cur = self.inflight_runs.load(Ordering::SeqCst);
            loop {
                if cur >= limit {
                    return false;
                }
                match self.inflight_runs.compare_exchange_weak(
                    cur,
                    cur + 1,
                    Ordering::SeqCst,
                    Ordering::SeqCst,
                ) {
                    Ok(_) => break,
                    Err(actual) => cur = actual,
                }
            }
        } else {
            // Only the queue knob is set; still hold a slot so release
            // stays symmetric (and notifies blocked waiters).
            self.inflight_runs.fetch_add(1, Ordering::SeqCst);
        }
        if self.max_queued_tasks > 0
            && self.pending_estimate().saturating_add(n_tasks) > self.max_queued_tasks
        {
            // Give the slot back; a waiter refused while we held it
            // re-checks on the notify (or its timer-parked backstop).
            self.inflight_runs.fetch_sub(1, Ordering::SeqCst);
            self.budget_ec.notify_all();
            return false;
        }
        true
    }

    /// Admits a graph run of `n_tasks` nodes under the pool's budget
    /// (PR 6). Returns `Ok(true)` if a slot was taken (the run must
    /// release it on completion), `Ok(false)` if admission is
    /// unlimited (both knobs 0 — the zero-cost default), and `Err(())`
    /// if the pool is overloaded. `block` callers park on the budget
    /// eventcount until a slot frees instead of failing; the graph
    /// layer never blocks Low-class runs (shed-first policy).
    pub(crate) fn admit_run(
        self: &Arc<Self>,
        n_tasks: usize,
        low_class: bool,
        block: bool,
    ) -> Result<bool, ()> {
        if self.max_inflight_runs == 0 && self.max_queued_tasks == 0 {
            return Ok(false);
        }
        let class = low_class as u32;
        if self.try_take_slot(n_tasks, low_class) {
            self.record_flight(
                self.flight_lane_of_caller(),
                EventKind::AdmitOk,
                class,
                self.inflight_runs.load(Ordering::Relaxed) as u64,
            );
            return Ok(true);
        }
        if !block {
            if low_class {
                self.shed_runs.fetch_add(1, Ordering::Relaxed);
            }
            self.record_flight(self.flight_lane_of_caller(), EventKind::AdmitShed, class, 0);
            return Err(());
        }
        self.record_flight(self.flight_lane_of_caller(), EventKind::AdmitBlocked, class, 0);
        // Park until a slot is released. Slot releases broadcast on
        // budget_ec, but queue-pressure admission (`max_queued_tasks`)
        // frees capacity through task completions that do **not**
        // notify it — so a timer-parked backstop chain re-wakes the
        // waiters with exponentially decaying urgency (1 → 5 ms)
        // instead of the retired per-waiter 1 ms timeout poll: one
        // timer-heap entry for the whole park, no periodic syscall
        // wakeups on each blocked submitter (PR 7).
        let live = Arc::new(AtomicBool::new(true));
        spawn_backstop_chain(
            Arc::downgrade(self),
            live.clone(),
            Duration::from_millis(1),
            Duration::from_millis(5),
            Backstop::Budget,
        );
        loop {
            if self.try_take_slot(n_tasks, low_class) {
                live.store(false, Ordering::SeqCst);
                self.record_flight(self.flight_lane_of_caller(), EventKind::AdmitOk, class, 0);
                return Ok(true);
            }
            let token = self.budget_ec.prepare_wait();
            if self.try_take_slot(n_tasks, low_class) {
                self.budget_ec.cancel_wait(token);
                live.store(false, Ordering::SeqCst);
                self.record_flight(self.flight_lane_of_caller(), EventKind::AdmitOk, class, 0);
                return Ok(true);
            }
            self.budget_ec.commit_wait(token);
        }
    }

    /// Flight lane for the current thread (PR 9): a worker of this
    /// pool records into its own lane, everyone else into the shared
    /// external lane.
    #[inline]
    pub(crate) fn flight_lane_of_caller(&self) -> usize {
        LOCAL.with(|l| match l.get() {
            Some(lw) if std::ptr::eq(lw.pool, self as *const PoolInner) => lw.index,
            _ => self.flight.as_ref().map_or(0, |f| f.external_lane()),
        })
    }

    /// Releases an admission slot taken by [`PoolInner::admit_run`]
    /// (`Ok(true)`) and wakes blocked admission waiters. Called
    /// exactly once per admitted run, from the run's completion path.
    pub(crate) fn release_run_slot(&self) {
        self.inflight_runs.fetch_sub(1, Ordering::SeqCst);
        self.budget_ec.notify_all();
    }

    /// Folds one observed dispatch-queue delay into the pool's EWMA
    /// (PR 7): `ewma += (sample - ewma) / 8`. See the field docs for
    /// why the racy read-modify-write is acceptable.
    pub(crate) fn observe_queue_delay(&self, delay: Duration) {
        let sample = delay.as_nanos().min(u64::MAX as u128) as u64;
        if let Some(h) = &self.hists {
            h.queue_delay.record(sample);
        }
        let cur = self.queue_delay_ewma_ns.load(Ordering::Relaxed);
        let next = if cur == 0 {
            sample // first observation seeds the average
        } else {
            cur.wrapping_add((sample / 8).wrapping_sub(cur / 8))
        };
        self.queue_delay_ewma_ns.store(next, Ordering::Relaxed);
    }

    /// Current dispatch-queue-delay EWMA (PR 7); zero until the first
    /// [`PoolInner::observe_queue_delay`].
    pub(crate) fn queue_delay_ewma(&self) -> Duration {
        Duration::from_nanos(self.queue_delay_ewma_ns.load(Ordering::Relaxed))
    }

    /// p99 of the queue-delay histogram (PR 9), once it has warmed past
    /// [`crate::obs::HIST_MIN_SAMPLES`] samples — `None` while cold or
    /// when histograms are disabled, in which case SLO checks fall
    /// back to the EWMA.
    pub(crate) fn queue_delay_p99(&self) -> Option<Duration> {
        let h = self.hists.as_ref()?;
        let s = h.queue_delay.snapshot();
        (s.count >= crate::obs::HIST_MIN_SAMPLES).then(|| Duration::from_nanos(s.quantile(0.99)))
    }

    /// The flight recorder, if enabled (PR 9).
    #[inline]
    pub(crate) fn flight(&self) -> Option<&Arc<FlightRecorder>> {
        self.flight.as_ref()
    }

    /// Pool-level histograms, if enabled (PR 9).
    #[inline]
    pub(crate) fn hists(&self) -> Option<&PoolHists> {
        self.hists.as_ref()
    }

    /// Nanoseconds since the pool's observability epoch, clamped to
    /// ≥ 1 so 0 can mean "never stamped" in span arrays (PR 9).
    #[inline]
    pub(crate) fn now_ns(&self) -> u64 {
        (self.epoch.elapsed().as_nanos() as u64).max(1)
    }

    /// Records one flight event into `lane` if the recorder is on
    /// (PR 9) — the no-recorder case is one branch.
    #[inline]
    pub(crate) fn record_flight(&self, lane: usize, kind: EventKind, a: u32, b: u64) {
        if let Some(f) = &self.flight {
            f.record(lane, kind, a, b);
        }
    }

    /// Stashes an automatic flight dump taken on a run failure (PR 9);
    /// retrieved via [`ThreadPool::last_flight_dump`].
    pub(crate) fn stash_flight_dump(&self, dump: FlightDump) {
        *self.last_dump.lock().unwrap() = Some(dump);
    }

    /// Clone of the stashed auto-dump, if any (PR 9).
    pub(crate) fn take_last_flight_dump(&self) -> Option<FlightDump> {
        self.last_dump.lock().unwrap().clone()
    }

    /// One random-start batched-steal sweep over the victim deques in
    /// `victims` (a shard's member range), skipping `index`. Shared by
    /// both levels of the two-level sweep. Returns the stolen job, if
    /// any, and ORs lost-race observations into `saw_retry`.
    fn try_steal_range(
        &self,
        index: usize,
        local: &Worker<RawTask>,
        victims: std::ops::Range<usize>,
        rng: &mut XorShift64Star,
        saw_retry: &mut bool,
    ) -> Option<RawTask> {
        let m = &self.metrics[index];
        let len = victims.len();
        if len == 0 || (len == 1 && victims.start == index) {
            return None;
        }
        let start = victims.start + rng.next_below(len);
        for k in 0..len {
            let victim = victims.start + (start - victims.start + k) % len;
            if victim == index {
                continue;
            }
            let mut moved = 0u64;
            let result = if self.steal_batch {
                let (result, extra) = self.stealers[victim].steal_batch_and_pop_counted(local);
                if extra > 0 {
                    m.on_steal_batch(extra as u64);
                    // The moved tasks enter the local deque and are
                    // counted as pushes; their eventual pops keep
                    // executed() covering every task exactly once.
                    m.on_push_n(extra as u64);
                    moved = extra as u64;
                }
                result
            } else {
                self.stealers[victim].steal()
            };
            match result {
                Steal::Success(job) => {
                    m.on_steal();
                    self.record_flight(index, EventKind::Steal, victim as u32, moved);
                    return Some(job);
                }
                Steal::Retry => {
                    m.on_steal_failure();
                    self.record_flight(index, EventKind::StealFail, victim as u32, 0);
                    *saw_retry = true;
                }
                Steal::Empty => {}
            }
        }
        None
    }

    /// One attempt to find work — the **two-level sweep** (PR 5):
    ///
    /// 1. own deque;
    /// 2. home-shard injector;
    /// 3. same-shard victim deques (random start, batched steal);
    /// 4. remote shards in random rotation — each shard's injector,
    ///    then its member deques.
    ///
    /// Locality first, but every queue of every shard is visited
    /// before giving up, so cross-shard starvation is impossible. On a
    /// flat (single-shard) pool steps 2–3 cover everything and step 4
    /// vanishes — the exact pre-PR 5 sweep. Returns `(job, saw_retry)`.
    fn find_task(
        &self,
        index: usize,
        local: &Worker<RawTask>,
        rng: &mut XorShift64Star,
    ) -> (Option<RawTask>, bool) {
        let m = &self.metrics[index];
        if let Some(job) = local.pop() {
            m.on_pop();
            return (Some(job), false);
        }
        let home = self.topology.shard_of(index);
        if let Some(job) = self.shards[home].injector.pop() {
            m.on_injector_pop();
            return (Some(job), false);
        }
        let mut saw_retry = false;
        if let Some(job) =
            self.try_steal_range(index, local, self.topology.members(home), rng, &mut saw_retry)
        {
            return (Some(job), saw_retry);
        }
        let ns = self.num_shards();
        if ns > 1 {
            // Random rotation over the ns-1 remote shards.
            let start = rng.next_below(ns - 1);
            for j in 0..ns - 1 {
                let shard = (home + 1 + (start + j) % (ns - 1)) % ns;
                if let Some(job) = self.shards[shard].injector.pop() {
                    m.on_injector_pop();
                    m.on_remote_injector_pop();
                    return (Some(job), saw_retry);
                }
                if let Some(job) = self.try_steal_range(
                    index,
                    local,
                    self.topology.members(shard),
                    rng,
                    &mut saw_retry,
                ) {
                    m.on_remote_steal();
                    return (Some(job), saw_retry);
                }
            }
        }
        (None, saw_retry)
    }

    /// True if any work might be available (used to re-check before
    /// parking; conservative — may say true spuriously). Probes
    /// **every** shard's injector and every deque: the two-level
    /// re-check that makes a park safe no matter which shard the work
    /// landed in.
    fn any_work(&self) -> bool {
        !self.injectors_empty() || self.stealers.iter().any(|s| !s.is_empty())
    }

    /// Metrics index of the shared helper lane (caller-assist threads).
    #[inline]
    pub(crate) fn helper_lane(&self) -> usize {
        self.stealers.len()
    }

    /// True if the current thread is inside an [`PoolInner::assist_until`]
    /// scope for *this* pool — i.e. a task picked up by a caller-assist
    /// helper is executing. Used (together with worker-thread detection)
    /// to reject nested graph runs on the same pool.
    pub(crate) fn on_assisting_thread(&self) -> bool {
        ASSISTING.with(|a| std::ptr::eq(a.get(), self as *const PoolInner as *const ()))
    }

    /// Wakes every parked worker *and* any caller-assist thread parked
    /// on the eventcounts (the graph executor's run-complete signal).
    pub(crate) fn notify_all_workers(&self) {
        for shard in self.shards.iter() {
            shard.ec.notify_all();
        }
    }

    /// Wakes every thread parked in [`PoolInner::wait_run`] — the
    /// graph executor's run-completion signal for async handles. O(1)
    /// load when nobody is parked.
    pub(crate) fn notify_run_waiters(&self) {
        self.run_ec.notify_all();
    }

    /// Blocks until `is_done()` reports true **without** executing
    /// pool tasks — the completion-wait of an async run handle
    /// (`graph::RunHandle::wait` / `Drop`). Parks on the dedicated
    /// run eventcount, so work-arrival wakeups meant for workers are
    /// never swallowed; `is_done` must become true through pool task
    /// execution followed by [`PoolInner::notify_run_waiters`] (the
    /// SeqCst store/load pair plus the eventcount's prepare/re-check
    /// protocol then guarantee a parked waiter observes it, and a
    /// timer-parked backstop chain makes liveness independent of that
    /// reasoning — see [`PoolInner::wait_run_backstopped`]).
    ///
    /// On a thread that is already executing a task of this pool (a
    /// worker, or a caller-assist helper mid-task), parking could
    /// starve the very queues the awaited run needs — handle `Drop`
    /// still must not return before quiescence, so here the wait
    /// *drains* instead: it executes pool tasks (every worker deque is
    /// reachable through its stealer) until `is_done` flips.
    pub(crate) fn wait_run(self: &Arc<Self>, is_done: impl Fn() -> bool) {
        // Completions on this pool always notify run_ec, so the
        // backstop here is purely defensive; start it late and let it
        // decay so an idle waiter costs the timer heap almost nothing.
        self.wait_run_backstopped(is_done, Duration::from_millis(25));
    }

    /// [`PoolInner::wait_run`] with an explicit first-backstop delay
    /// (PR 7). Instead of the retired per-waiter 1 ms timeout poll,
    /// each park schedules one self-rescheduling entry on the
    /// `pool/timer.rs` min-heap that pokes `run_ec` at `initial`,
    /// `2·initial`, … up to `8·initial`, and defuses the moment the
    /// wait completes. Single-pool waits use a long defensive delay;
    /// the multi-pool fleet combinators (`graph::wait_all` /
    /// `wait_any`) pass 1 ms, because a completion on *another* pool
    /// never notifies this pool's run eventcount and the chain is what
    /// keeps the fleet wait live.
    pub(crate) fn wait_run_backstopped(
        self: &Arc<Self>,
        is_done: impl Fn() -> bool,
        initial: Duration,
    ) {
        if self.on_worker_thread() || self.on_assisting_thread() {
            let mut rng = XorShift64Star::from_entropy();
            while !is_done() {
                let (job, saw_retry) = self.helper_find_task(&mut rng);
                match job {
                    Some(job) => self.run_helper_job(job),
                    // A victim deque is mid-operation; retry shortly.
                    None if saw_retry => std::hint::spin_loop(),
                    // Remaining tasks of the run are executing on other
                    // threads; yield until they finish.
                    None => std::thread::yield_now(),
                }
            }
            return;
        }
        if is_done() {
            return;
        }
        let live = Arc::new(AtomicBool::new(true));
        spawn_backstop_chain(
            Arc::downgrade(self),
            live.clone(),
            initial,
            initial.saturating_mul(8),
            Backstop::RunWaiters,
        );
        loop {
            if is_done() {
                break;
            }
            let token = self.run_ec.prepare_wait();
            if is_done() {
                self.run_ec.cancel_wait(token);
                break;
            }
            self.run_ec.commit_wait(token);
        }
        live.store(false, Ordering::SeqCst);
    }

    /// One find-task attempt for a caller-assist helper: home-shard
    /// injector first (the helper's own submissions land there), then
    /// the remote shards' injectors, then a random-start single-task
    /// steal sweep over every deque. Helpers own no deque, so no
    /// batched stealing. Returns `(job, saw_retry)`.
    fn helper_find_task(&self, rng: &mut XorShift64Star) -> (Option<RawTask>, bool) {
        let m = &self.metrics[self.helper_lane()];
        let home = self.current_home_shard();
        let ns = self.num_shards();
        for k in 0..ns {
            let shard = (home + k) % ns;
            if let Some(job) = self.shards[shard].injector.pop() {
                m.on_injector_pop();
                if shard != home {
                    m.on_remote_injector_pop();
                }
                return (Some(job), false);
            }
        }
        let n = self.stealers.len();
        let start = rng.next_below(n);
        let mut saw_retry = false;
        for k in 0..n {
            match self.stealers[(start + k) % n].steal() {
                Steal::Success(job) => {
                    m.on_steal();
                    return (Some(job), saw_retry);
                }
                Steal::Retry => {
                    m.on_steal_failure();
                    saw_retry = true;
                }
                Steal::Empty => {}
            }
        }
        (None, saw_retry)
    }

    /// Executes one job on a helper (non-worker) thread: metrics go to
    /// the shared helper lane and the completion to the external
    /// counter cell, keeping the two-pass quiescence scan balanced.
    fn run_helper_job(self: &Arc<Self>, job: RawTask) {
        // Completion counting rides a drop guard (PR 6): if an unwind
        // ever escapes task containment, the quiescence scan must not
        // be left unbalanced — an uncounted completion would hang
        // wait_idle forever.
        struct HelperFinishGuard<'a>(&'a PoolInner);
        impl Drop for HelperFinishGuard<'_> {
            fn drop(&mut self) {
                let pool = self.0;
                pool.counters[pool.external_cell()].completed.fetch_add(1, Ordering::Release);
                // Mirror finish_job's wait_idle nudge (helpers have no
                // own deque to check).
                if pool.idle_waiters.load(Ordering::Acquire) != 0 && pool.injectors_empty() {
                    drop(pool.idle_mutex.lock().unwrap());
                    pool.idle_cv.notify_all();
                }
            }
        }
        let _finish = HelperFinishGuard(self);
        job.run(self, self.helper_lane());
    }

    /// Caller-assisted execution (graph executor, PR 2): runs pool
    /// tasks on the **calling** thread until `done()` reports true,
    /// parking on the eventcount only when there is genuinely nothing
    /// to take. The caller must not be a worker of this pool.
    ///
    /// `done` must become true through pool task execution (the graph
    /// run's final decrement) and be followed by
    /// [`PoolInner::notify_all_workers`]; the SeqCst store/load pair
    /// plus the eventcount's prepare/re-check protocol then guarantee
    /// a parked helper observes it. A 1 ms timeout backstop (same as
    /// `wait_idle`) makes liveness independent of that reasoning.
    ///
    /// Note: helpers execute whatever the queues hold, so tasks
    /// unrelated to the caller's graph run may execute on this thread.
    pub(crate) fn assist_until(self: &Arc<Self>, done: impl Fn() -> bool) {
        debug_assert!(!self.on_worker_thread(), "assist_until on a worker thread");
        let _assisting = AssistGuard::enter(self);
        // Park on the home shard the guard just assigned: completions
        // and home-shard submissions notify there first.
        let home_ec = &self.shards[self.current_home_shard()].ec;
        let mut rng = XorShift64Star::from_entropy();
        loop {
            if done() {
                return;
            }
            let (job, saw_retry) = self.helper_find_task(&mut rng);
            if let Some(job) = job {
                self.run_helper_job(job);
                continue;
            }
            if saw_retry {
                // A victim deque is mid-operation; back off a touch and
                // retry without parking.
                std::hint::spin_loop();
                continue;
            }
            let token = home_ec.prepare_wait();
            if done() || self.any_work() {
                home_ec.cancel_wait(token);
                continue;
            }
            home_ec.commit_wait_timeout(token, Duration::from_millis(1));
        }
    }

    /// Executes one job. Closure panics are contained inside the task
    /// vtable (counted via [`PoolInner::note_panic`]); graph nodes
    /// contain panics in `graph::execute_node`. (Executed counts are
    /// derived from pop/steal/injector counters — see metrics.rs.)
    ///
    /// The completion bump runs through a drop guard (PR 6): if an
    /// unwind ever escapes containment, `finish_job` still fires, so
    /// the quiescence counters stay balanced and the worker-loop
    /// revival catch resumes a pool whose `wait_idle` still works.
    pub(crate) fn run_job(self: &Arc<Self>, index: usize, job: RawTask) {
        struct FinishGuard<'a> {
            pool: &'a PoolInner,
            index: usize,
        }
        impl Drop for FinishGuard<'_> {
            fn drop(&mut self) {
                self.pool.finish_job(self.index);
            }
        }
        let _finish = FinishGuard { pool: self, index };
        job.run(self, index);
    }
}

/// Which eventcount a timer-parked wait backstop pokes (PR 7).
#[derive(Clone, Copy)]
enum Backstop {
    /// `budget_ec` — blocked admission (`PoolInner::admit_run`).
    Budget,
    /// `run_ec` — run-completion waiters (`PoolInner::wait_run`).
    RunWaiters,
}

/// Schedules one self-rescheduling backstop entry on the
/// `pool/timer.rs` min-heap: at `delay` it re-wakes the parked waiters
/// of `which`, then re-arms with the delay doubled (capped at `max`)
/// while `live` stays set. This replaces the retired per-waiter 1 ms
/// `commit_wait_timeout` polls (PR 7): a blocked thread now parks
/// indefinitely on its eventcount and the timer thread carries the
/// liveness guarantee — one heap entry per parked wait instead of a
/// thousand timed wakeups per waiter-second. The chain self-defuses
/// when `live` clears or the pool is dropped (`Weak` upgrade fails),
/// so a stale entry after the wait completes is a no-op.
fn spawn_backstop_chain(
    weak: Weak<PoolInner>,
    live: Arc<AtomicBool>,
    delay: Duration,
    max: Duration,
    which: Backstop,
) {
    timer::schedule_after(
        delay,
        Box::new(move || {
            if !live.load(Ordering::SeqCst) {
                return;
            }
            if let Some(pool) = weak.upgrade() {
                match which {
                    Backstop::Budget => pool.budget_ec.notify_all(),
                    Backstop::RunWaiters => pool.run_ec.notify_all(),
                }
                let next = delay.saturating_mul(2).min(max);
                spawn_backstop_chain(Arc::downgrade(&pool), live, next, max, which);
            }
        }),
    );
}

fn worker_loop(inner: Arc<PoolInner>, index: usize, queue: Worker<RawTask>) {
    // Live-worker accounting (PR 6): the decrement rides a drop guard
    // so even an unwind past the revival catch below (impossible by
    // construction, but this is the robustness layer) keeps the count
    // honest.
    inner.alive_workers.fetch_add(1, Ordering::SeqCst);
    struct AliveGuard<'a>(&'a PoolInner);
    impl Drop for AliveGuard<'_> {
        fn drop(&mut self) {
            self.0.alive_workers.fetch_sub(1, Ordering::SeqCst);
        }
    }
    let _alive = AliveGuard(&inner);
    LOCAL.with(|l| {
        l.set(Some(LocalWorker {
            pool: Arc::as_ptr(&inner),
            queue: &queue as *const Worker<RawTask>,
            index,
        }))
    });
    let _guard = LocalGuard;
    let mut rng = XorShift64Star::from_entropy();
    // This worker's sleep/wake domain (PR 5): it parks on its home
    // shard's eventcount, which producers probe first when routing a
    // wakeup toward this shard.
    let home_ec = &inner.shards[inner.topology.shard_of(index)].ec;
    let flat = inner.num_shards() == 1;
    // `parks` counts transitions INTO idleness, not commit_wait calls:
    // a multi-shard park wakes every SHARD_PARK_BACKSTOP to re-check,
    // and counting each backstop cycle would make an idle sharded pool
    // look like it thrashes sleep/wake next to the flat arm in ABL-8.
    let mut counted_park = false;

    'outer: loop {
        // Work until dry, spinning through `spin_rounds` extra sweeps.
        // The sweep runs under catch_unwind (PR 6): task containment
        // (vtable + execute_node) means no panic reaches this frame by
        // construction, but if one ever does, the worker records it
        // and **revives in place** — deque and TLS registration live
        // in this very frame, so identity survives and the pool never
        // silently shrinks. run_job's drop guard has already kept the
        // completion counters balanced on that path.
        let dry = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut spins = 0;
            loop {
                let (job, saw_retry) = inner.find_task(index, &queue, &mut rng);
                match job {
                    Some(job) => {
                        if counted_park {
                            // End of an idle spell: the park event's
                            // counterpart (PR 9).
                            inner.record_flight(index, EventKind::Wake, 0, 0);
                        }
                        inner.run_job(index, job);
                        spins = 0;
                        counted_park = false;
                    }
                    None if saw_retry => {
                        // Someone is mid-operation on a victim deque;
                        // back off a touch and retry without parking.
                        std::hint::spin_loop();
                    }
                    None => {
                        spins += 1;
                        if spins > inner.spin_rounds {
                            break;
                        }
                        std::thread::yield_now();
                    }
                }
            }
        }));
        if dry.is_err() {
            inner.worker_revivals.fetch_add(1, Ordering::Relaxed);
            continue 'outer;
        }

        // Park protocol: register as sleeper on the home shard's
        // eventcount, re-check EVERY shard's queues (any_work — the
        // two-level re-check that pairs with notify_shard's waiter
        // scan), sleep.
        let token = home_ec.prepare_wait();
        if inner.shutdown.load(Ordering::SeqCst) {
            home_ec.cancel_wait(token);
            // Drain remaining work before exiting so drop() does not
            // strand submitted tasks.
            while let (Some(job), _) = inner.find_task(index, &queue, &mut rng) {
                inner.run_job(index, job);
            }
            break 'outer;
        }
        if inner.any_work() {
            home_ec.cancel_wait(token);
            continue;
        }
        if !counted_park {
            inner.metrics[index].on_park();
            inner.record_flight(index, EventKind::Park, 0, 0);
            counted_park = true;
        }
        if flat {
            // Single eventcount: the textbook protocol, park unbounded.
            home_ec.commit_wait(token);
        } else {
            // Cross-eventcount wakeup targeting: keep the liveness
            // backstop (see SHARD_PARK_BACKSTOP).
            home_ec.commit_wait_timeout(token, SHARD_PARK_BACKSTOP);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::time::Duration;

    #[test]
    fn executes_submitted_tasks() {
        let pool = ThreadPool::new(2);
        let count = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let count = count.clone();
            pool.submit(move || {
                count.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_idle();
        assert_eq!(count.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn zero_threads_clamps_to_one() {
        let pool = ThreadPool::new(0);
        assert_eq!(pool.num_threads(), 1);
        let hit = Arc::new(AtomicUsize::new(0));
        let h = hit.clone();
        pool.submit(move || {
            h.fetch_add(1, Ordering::Relaxed);
        });
        pool.wait_idle();
        assert_eq!(hit.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn tasks_submitting_tasks() {
        // Recursive fan-out: each task spawns children; wait_idle must
        // cover transitively spawned work.
        let pool = Arc::new(ThreadPool::new(3));
        let count = Arc::new(AtomicUsize::new(0));
        fn spawn(pool: &Arc<ThreadPool>, count: &Arc<AtomicUsize>, depth: usize) {
            count.fetch_add(1, Ordering::Relaxed);
            if depth == 0 {
                return;
            }
            for _ in 0..2 {
                let (p, c) = (pool.clone(), count.clone());
                pool.submit(move || spawn(&p, &c, depth - 1));
            }
        }
        spawn(&pool, &count, 0); // count the root call manually
        let (p, c) = (pool.clone(), count.clone());
        pool.submit(move || spawn(&p, &c, 9));
        pool.wait_idle();
        // Root manual call (1) + full binary tree of depth 9 (2^10 - 1).
        assert_eq!(count.load(Ordering::Relaxed), 1 + (1 << 10) - 1);
    }

    #[test]
    fn worker_submit_uses_local_queue() {
        let pool = ThreadPool::new(1);
        let pushed = Arc::new(AtomicUsize::new(0));
        let p = pushed.clone();
        pool.submit(move || {
            p.fetch_add(1, Ordering::Relaxed);
        });
        pool.wait_idle();
        // Now submit from inside a task and check the metrics counted a
        // local push.
        let inner_done = Arc::new(AtomicUsize::new(0));
        let d = inner_done.clone();
        struct PoolPtr(*const ThreadPool);
        unsafe impl Send for PoolPtr {}
        let pp = PoolPtr(&pool as *const ThreadPool);
        pool.submit(move || {
            // Capture the whole wrapper (edition-2021 closures would
            // otherwise capture only the raw-pointer field).
            let pp = pp;
            // SAFETY: `pool` outlives this task; wait_idle below joins it.
            let pool = unsafe { &*pp.0 };
            let d2 = d.clone();
            pool.submit(move || {
                d2.fetch_add(1, Ordering::Relaxed);
            });
        });
        pool.wait_idle();
        assert_eq!(inner_done.load(Ordering::Relaxed), 1);
        assert!(pool.metrics().total().pushes >= 1, "inner submit should hit the local deque");
    }

    #[test]
    fn panicking_task_is_contained() {
        let pool = ThreadPool::new(2);
        pool.submit(|| panic!("boom"));
        let ok = Arc::new(AtomicUsize::new(0));
        let o = ok.clone();
        pool.submit(move || {
            o.fetch_add(1, Ordering::Relaxed);
        });
        pool.wait_idle();
        assert_eq!(pool.panic_count(), 1);
        assert_eq!(ok.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn boxed_panicking_task_is_contained() {
        // The spill path must contain panics identically.
        let pool = ThreadPool::with_config(PoolConfig {
            num_threads: 1,
            inline_tasks: false,
            ..PoolConfig::default()
        });
        pool.submit(|| panic!("boxed boom"));
        pool.wait_idle();
        assert_eq!(pool.panic_count(), 1);
    }

    #[test]
    fn drop_drains_submitted_work() {
        let count = Arc::new(AtomicUsize::new(0));
        {
            let pool = ThreadPool::new(2);
            for _ in 0..50 {
                let count = count.clone();
                pool.submit(move || {
                    std::thread::sleep(Duration::from_micros(100));
                    count.fetch_add(1, Ordering::Relaxed);
                });
            }
            // Drop without wait_idle.
        }
        assert_eq!(count.load(Ordering::Relaxed), 50);
    }

    #[test]
    fn wait_idle_on_idle_pool_returns_immediately() {
        let pool = ThreadPool::new(2);
        pool.wait_idle();
        pool.wait_idle();
    }

    #[test]
    fn pending_estimate_settles_to_zero() {
        let pool = ThreadPool::new(2);
        assert_eq!(pool.pending(), 0);
        let count = Arc::new(AtomicUsize::new(0));
        for _ in 0..64 {
            let c = count.clone();
            pool.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_idle();
        assert_eq!(pool.pending(), 0);
        assert_eq!(count.load(Ordering::Relaxed), 64);
    }

    #[test]
    fn current_worker_identity() {
        let pool = Arc::new(ThreadPool::new(2));
        assert_eq!(pool.current_worker(), None);
        let p = pool.clone();
        let (tx, rx) = std::sync::mpsc::channel();
        pool.submit(move || {
            tx.send(p.current_worker()).unwrap();
        });
        let idx = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(matches!(idx, Some(i) if i < 2));
        pool.wait_idle();
    }

    #[test]
    fn lock_free_injector_config() {
        let pool = ThreadPool::with_config(PoolConfig {
            num_threads: 2,
            injector: InjectorKind::LockFree,
            ..PoolConfig::default()
        });
        let count = Arc::new(AtomicUsize::new(0));
        for _ in 0..500 {
            let count = count.clone();
            pool.submit(move || {
                count.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_idle();
        assert_eq!(count.load(Ordering::Relaxed), 500);
    }

    #[test]
    fn many_waves_of_work_with_parking_between() {
        let pool = ThreadPool::new(2);
        let count = Arc::new(AtomicUsize::new(0));
        for wave in 0..20 {
            for _ in 0..10 {
                let count = count.clone();
                pool.submit(move || {
                    count.fetch_add(1, Ordering::Relaxed);
                });
            }
            pool.wait_idle();
            assert_eq!(count.load(Ordering::Relaxed), (wave + 1) * 10);
            // Let workers park so the next wave exercises wakeup.
            std::thread::sleep(Duration::from_millis(2));
        }
    }

    #[test]
    fn every_optimization_toggle_is_correct() {
        // The three hot-path optimizations must be behaviour-preserving
        // individually and in the all-off configuration.
        let variants: [(&str, PoolConfig); 5] = [
            ("all-on", PoolConfig::default()),
            ("boxed-tasks", PoolConfig { inline_tasks: false, ..PoolConfig::default() }),
            ("single-steal", PoolConfig { steal_batch: false, ..PoolConfig::default() }),
            ("per-task-wake", PoolConfig { batched_wakeups: false, ..PoolConfig::default() }),
            (
                "all-off",
                PoolConfig {
                    inline_tasks: false,
                    steal_batch: false,
                    batched_wakeups: false,
                    ..PoolConfig::default()
                },
            ),
        ];
        for (name, config) in variants {
            let pool = ThreadPool::with_config(PoolConfig { num_threads: 3, ..config });
            let count = Arc::new(AtomicUsize::new(0));
            for _ in 0..1000 {
                let c = count.clone();
                pool.submit(move || {
                    c.fetch_add(1, Ordering::Relaxed);
                });
            }
            pool.wait_idle();
            assert_eq!(count.load(Ordering::Relaxed), 1000, "{name}");
        }
    }

    #[test]
    fn metrics_include_shared_helper_lane() {
        // n worker lanes + 1 helper lane for caller-assist threads.
        let pool = ThreadPool::new(2);
        assert_eq!(pool.metrics().workers.len(), 3);
        assert_eq!(pool.inner().helper_lane(), 2);
    }

    #[test]
    fn assist_until_executes_queued_work_on_calling_thread() {
        // Pool with zero spinning and a task queued while we assist:
        // the helper must be able to drain it (possibly racing the
        // workers) and return as soon as `done` flips.
        let pool = ThreadPool::new(1);
        let count = Arc::new(AtomicUsize::new(0));
        for _ in 0..64 {
            let c = count.clone();
            pool.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        let c = count.clone();
        pool.inner().assist_until(move || c.load(Ordering::Relaxed) >= 64);
        assert_eq!(count.load(Ordering::Relaxed), 64);
        pool.wait_idle();
        assert_eq!(pool.pending(), 0);
    }

    #[test]
    fn wait_run_parks_until_predicate_flips() {
        // The non-assisting run-completion wait: the caller parks on
        // the dedicated run eventcount and is released by
        // notify_run_waiters (with the timer-parked backstop chain
        // behind it).
        let pool = ThreadPool::new(2);
        let done = Arc::new(AtomicUsize::new(0));
        let d = done.clone();
        let inner = pool.inner().clone();
        pool.submit(move || {
            std::thread::sleep(Duration::from_millis(20));
            d.store(1, Ordering::SeqCst);
            inner.notify_run_waiters();
        });
        let d = done.clone();
        pool.inner().wait_run(|| d.load(Ordering::SeqCst) == 1);
        assert_eq!(done.load(Ordering::SeqCst), 1);
        pool.wait_idle();
    }

    #[test]
    fn wait_run_on_worker_thread_drains_tasks() {
        // From inside a pool task, wait_run must execute queued tasks
        // itself (parking the only worker would deadlock) — the
        // handle-dropped-on-a-worker path.
        let pool = Arc::new(ThreadPool::new(1));
        let (tx, rx) = std::sync::mpsc::channel();
        let p = pool.clone();
        pool.submit(move || {
            let hit = Arc::new(AtomicUsize::new(0));
            for _ in 0..8 {
                let h = hit.clone();
                p.submit(move || {
                    h.fetch_add(1, Ordering::SeqCst);
                });
            }
            let h = hit.clone();
            p.inner().wait_run(|| h.load(Ordering::SeqCst) == 8);
            tx.send(hit.load(Ordering::SeqCst)).unwrap();
        });
        assert_eq!(rx.recv_timeout(Duration::from_secs(10)).unwrap(), 8);
        pool.wait_idle();
    }

    #[test]
    fn default_small_pool_is_flat() {
        // Pools of up to DEFAULT_SHARD_WORKERS workers collapse to one
        // shard under the auto setting — the pre-PR 5 shape.
        let pool = ThreadPool::new(2);
        assert_eq!(pool.num_shards(), 1);
        let snap = pool.metrics();
        assert_eq!(snap.shards.len(), 1);
        assert_eq!(snap.shards[0].workers, (0, 2));
        assert_eq!(snap.shard_imbalance(), 0.0);
    }

    #[test]
    fn explicit_shard_size_splits_pool() {
        let pool = ThreadPool::with_config(PoolConfig {
            num_threads: 4,
            shard_size: 2,
            ..PoolConfig::default()
        });
        assert_eq!(pool.num_shards(), 2);
        let snap = pool.metrics();
        assert_eq!(snap.shards.len(), 2);
        assert_eq!(snap.shards[0].workers, (0, 2));
        assert_eq!(snap.shards[1].workers, (2, 4));
        // The sharded pool still executes everything exactly once.
        let count = Arc::new(AtomicUsize::new(0));
        for _ in 0..500 {
            let c = count.clone();
            pool.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_idle();
        assert_eq!(count.load(Ordering::Relaxed), 500);
    }

    #[test]
    fn submit_to_shard_lands_in_target_injector() {
        // Workers wedged on gates -> the pinned submissions must sit in
        // the chosen shard's injector, observable via the depth
        // snapshot, and still execute after release.
        let pool = ThreadPool::with_config(PoolConfig {
            num_threads: 2,
            shard_size: 1,
            spin_rounds: 0,
            ..PoolConfig::default()
        });
        let gate = Arc::new(AtomicUsize::new(0));
        let started = Arc::new(AtomicUsize::new(0));
        for _ in 0..2 {
            let (g, s) = (gate.clone(), started.clone());
            pool.submit(move || {
                s.fetch_add(1, Ordering::SeqCst);
                while g.load(Ordering::SeqCst) == 0 {
                    std::thread::yield_now();
                }
            });
        }
        while started.load(Ordering::SeqCst) < 2 {
            std::thread::yield_now();
        }
        let hits = Arc::new(AtomicUsize::new(0));
        for _ in 0..8 {
            let h = hits.clone();
            pool.submit_to_shard(1, move || {
                h.fetch_add(1, Ordering::SeqCst);
            });
        }
        let snap = pool.metrics();
        assert_eq!(snap.shards[1].injector_depth, 8);
        assert_eq!(snap.shards[0].injector_depth, 0);
        assert!(snap.shard_imbalance() > 1.0);
        gate.store(1, Ordering::SeqCst);
        pool.wait_idle();
        assert_eq!(hits.load(Ordering::SeqCst), 8);
        // Out-of-range shards clamp instead of panicking.
        let h = hits.clone();
        pool.submit_to_shard(999, move || {
            h.fetch_add(1, Ordering::SeqCst);
        });
        pool.wait_idle();
        assert_eq!(hits.load(Ordering::SeqCst), 9);
    }

    #[test]
    fn striped_cursor_is_per_pool() {
        // Interleaved external submissions to TWO sharded pools from
        // one thread must round-robin within EACH pool — a cursor
        // shared across pools would alias (constant parity per pool)
        // and pile every task of a pool onto one shard.
        let mk = || {
            ThreadPool::with_config(PoolConfig {
                num_threads: 2,
                shard_size: 1,
                spin_rounds: 0,
                ..PoolConfig::default()
            })
        };
        let (pool_a, pool_b) = (mk(), mk());
        // Wedge all four workers so staged submissions stay queued.
        let gate = Arc::new(AtomicUsize::new(0));
        let started = Arc::new(AtomicUsize::new(0));
        for pool in [&pool_a, &pool_b] {
            for _ in 0..2 {
                let (g, s) = (gate.clone(), started.clone());
                pool.submit(move || {
                    s.fetch_add(1, Ordering::SeqCst);
                    while g.load(Ordering::SeqCst) == 0 {
                        std::thread::yield_now();
                    }
                });
            }
        }
        while started.load(Ordering::SeqCst) < 4 {
            std::thread::yield_now();
        }
        for _ in 0..4 {
            pool_a.submit(|| {});
            pool_b.submit(|| {});
        }
        for (name, pool) in [("a", &pool_a), ("b", &pool_b)] {
            let snap = pool.metrics();
            assert_eq!(
                (snap.shards[0].injector_depth, snap.shards[1].injector_depth),
                (2, 2),
                "pool {name}: alternating submits must alternate shards"
            );
        }
        gate.store(1, Ordering::SeqCst);
        pool_a.wait_idle();
        pool_b.wait_idle();
    }

    #[test]
    fn per_worker_shards_still_share_all_work() {
        // shard_size=1: every worker is its own shard; level-2 of the
        // sweep is the only cross-worker path and must still deliver
        // everything.
        let pool = ThreadPool::with_config(PoolConfig {
            num_threads: 3,
            shard_size: 1,
            ..PoolConfig::default()
        });
        assert_eq!(pool.num_shards(), 3);
        let count = Arc::new(AtomicUsize::new(0));
        for _ in 0..300 {
            let c = count.clone();
            pool.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_idle();
        assert_eq!(count.load(Ordering::Relaxed), 300);
    }

    #[test]
    fn sharded_pool_toggles_remain_correct() {
        // Sharding composed with each hot-path toggle off.
        for (name, config) in [
            ("sharded-default", PoolConfig { shard_size: 2, ..PoolConfig::default() }),
            (
                "sharded-all-off",
                PoolConfig {
                    shard_size: 2,
                    inline_tasks: false,
                    steal_batch: false,
                    batched_wakeups: false,
                    ..PoolConfig::default()
                },
            ),
            (
                "sharded-lockfree",
                PoolConfig {
                    shard_size: 2,
                    injector: InjectorKind::LockFree,
                    ..PoolConfig::default()
                },
            ),
        ] {
            let pool = ThreadPool::with_config(PoolConfig { num_threads: 4, ..config });
            assert_eq!(pool.num_shards(), 2, "{name}");
            let count = Arc::new(AtomicUsize::new(0));
            for _ in 0..1000 {
                let c = count.clone();
                pool.submit(move || {
                    c.fetch_add(1, Ordering::Relaxed);
                });
            }
            pool.wait_idle();
            assert_eq!(count.load(Ordering::Relaxed), 1000, "{name}");
        }
    }

    #[test]
    fn assist_until_on_sharded_pool() {
        // The helper gets a home shard on entry and must still drain
        // work from every shard.
        let pool = ThreadPool::with_config(PoolConfig {
            num_threads: 2,
            shard_size: 1,
            ..PoolConfig::default()
        });
        let count = Arc::new(AtomicUsize::new(0));
        for shard in 0..2 {
            for _ in 0..32 {
                let c = count.clone();
                pool.submit_to_shard(shard, move || {
                    c.fetch_add(1, Ordering::Relaxed);
                });
            }
        }
        let c = count.clone();
        pool.inner().assist_until(move || c.load(Ordering::Relaxed) >= 64);
        assert_eq!(count.load(Ordering::Relaxed), 64);
        pool.wait_idle();
    }

    #[test]
    fn batch_submit_from_external_thread() {
        // submit_job_batch through the injector path: counters, wake,
        // and delivery must all line up.
        let pool = ThreadPool::new(2);
        let count = Arc::new(AtomicUsize::new(0));
        let jobs: Vec<RawTask> = (0..100)
            .map(|_| {
                let c = count.clone();
                RawTask::closure(move || {
                    c.fetch_add(1, Ordering::Relaxed);
                })
            })
            .collect();
        pool.inner().submit_job_batch(jobs.into_iter());
        pool.wait_idle();
        assert_eq!(count.load(Ordering::Relaxed), 100);
        assert_eq!(pool.pending(), 0);
    }
}
