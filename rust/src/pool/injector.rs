//! Global injection queue for external submissions.
//!
//! The Chase–Lev deque's bottom end is owner-only, so threads that are
//! *not* workers of the pool (e.g. `main` submitting the root task, or
//! an I/O thread feeding the pool) cannot push to a worker deque. The
//! paper's implementation routes such submissions through a shared
//! queue; workers treat it as one more steal victim.
//!
//! Two implementations behind one API:
//! * [`MutexInjector`] — `Mutex<VecDeque>`; dead simple, and since the
//!   injector is off the hot path in all paper benchmarks (a single
//!   root submission, after which all spawning happens inside workers),
//!   this is the default.
//! * [`SegQueue`] — a lock-free Michael–Scott-style segmented queue
//!   (64-slot segments, per-slot ready flags). Used by the
//!   `injector` ablation in `benches/ablations.rs` to show the choice
//!   does not matter for graph workloads (and does for injector-heavy
//!   ones).

use std::collections::VecDeque;
use std::mem::MaybeUninit;
use std::ptr;
use std::sync::atomic::{AtomicBool, AtomicPtr, AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::util::CachePadded;

/// Number of priority lanes the pool's injection queue is split into
/// (PR 4). Lane 0 is the most urgent; lane `NUM_LANES - 1` the least.
/// Four lanes are enough to compose a run's priority class
/// (High/Normal/Low) with a node's critical-path standing (top-half /
/// bottom-half rank) — see `graph::schedule::lane_compose`.
pub const NUM_LANES: usize = 4;

/// Lane used by submissions with no priority information: plain
/// `ThreadPool::submit`, graph runs with priority lanes disabled, and
/// Normal-class critical nodes. Sits above Normal-class non-critical
/// work and below High-class work, so untagged tasks are neither
/// starved nor favoured.
pub const DEFAULT_LANE: u8 = 1;

/// Every `STARVATION_TICK`-th pop scans the lanes lowest-priority
/// first, so a saturated high lane cannot starve low-lane work forever
/// (the starvation bound the run-class design promises). Prime, so the
/// reversed pops do not beat against power-of-two submission patterns.
const STARVATION_TICK: usize = 61;

/// Common interface for injection queues.
pub trait Injector<T>: Send + Sync {
    /// Enqueues a value (multi-producer).
    fn push(&self, value: T);
    /// Enqueues a burst of values. The default loops over [`Injector::push`];
    /// implementations with per-push synchronization cost (a lock) override
    /// it to pay that cost once per burst — the pool's batched-submission
    /// path (`submit_job_batch`) is the caller.
    fn push_batch(&self, values: &mut dyn Iterator<Item = T>) {
        for v in values {
            self.push(v);
        }
    }
    /// Dequeues a value (multi-consumer).
    fn pop(&self) -> Option<T>;
    /// Approximate emptiness (used before parking; may be stale).
    fn is_empty(&self) -> bool;
    /// Approximate length.
    fn len(&self) -> usize;
}

/// Mutex-protected FIFO injector (default).
#[derive(Default)]
pub struct MutexInjector<T> {
    queue: Mutex<VecDeque<T>>,
    /// Fast-path emptiness flag so workers polling an empty injector
    /// don't take the lock at all.
    maybe_nonempty: AtomicBool,
}

impl<T> MutexInjector<T> {
    /// Creates an empty injector.
    pub fn new() -> Self {
        Self {
            queue: Mutex::new(VecDeque::new()),
            maybe_nonempty: AtomicBool::new(false),
        }
    }
}

impl<T: Send> Injector<T> for MutexInjector<T> {
    fn push(&self, value: T) {
        let mut q = self.queue.lock().unwrap();
        q.push_back(value);
        self.maybe_nonempty.store(true, Ordering::Release);
    }

    fn push_batch(&self, values: &mut dyn Iterator<Item = T>) {
        // One lock acquisition for the whole burst.
        let mut q = self.queue.lock().unwrap();
        let before = q.len();
        q.extend(values);
        if q.len() > before {
            self.maybe_nonempty.store(true, Ordering::Release);
        }
    }

    fn pop(&self) -> Option<T> {
        if !self.maybe_nonempty.load(Ordering::Acquire) {
            return None;
        }
        let mut q = self.queue.lock().unwrap();
        let v = q.pop_front();
        if q.is_empty() {
            self.maybe_nonempty.store(false, Ordering::Release);
        }
        v
    }

    fn is_empty(&self) -> bool {
        !self.maybe_nonempty.load(Ordering::Acquire)
    }

    fn len(&self) -> usize {
        self.queue.lock().unwrap().len()
    }
}

const SEG_SHIFT: usize = 6;
const SEG_CAP: usize = 1 << SEG_SHIFT; // 64 slots per segment

struct Slot<T> {
    value: MaybeUninit<T>,
    ready: AtomicBool,
}

struct Segment<T> {
    /// Ticket index of `slots[0]` — immutable after allocation, so a
    /// cached segment pointer is self-describing (no separate racy
    /// base counter).
    base: usize,
    slots: Box<[Slot<T>]>,
    next: AtomicPtr<Segment<T>>,
}

impl<T> Segment<T> {
    fn alloc(base: usize) -> *mut Segment<T> {
        let slots: Box<[Slot<T>]> = (0..SEG_CAP)
            .map(|_| Slot {
                value: MaybeUninit::uninit(),
                ready: AtomicBool::new(false),
            })
            .collect();
        Box::into_raw(Box::new(Segment {
            base,
            slots,
            next: AtomicPtr::new(ptr::null_mut()),
        }))
    }
}

/// Lock-free segmented MPMC FIFO queue.
///
/// `head`/`tail` are global ticket counters; a ticket maps to
/// `(segment_index, slot)`. Producers claim a ticket with `fetch_add`,
/// walk/extend the segment list, write the value and set `ready`.
/// Consumers claim a ticket below `tail` with CAS and spin briefly on
/// `ready` (a producer that claimed the slot is about to fill it).
/// Segments are retired when fully consumed; retirement is deferred to
/// `Drop` (bounded: queue lives as long as the pool).
pub struct SegQueue<T> {
    head: AtomicUsize,
    tail: AtomicUsize,
    /// Cached segment containing (roughly) the head ticket; may lag,
    /// never freed before `Drop`, so walking forward from it is safe.
    head_seg: AtomicPtr<Segment<T>>,
    /// Cached segment containing (roughly) the tail ticket.
    tail_seg: AtomicPtr<Segment<T>>,
    /// First segment ever allocated (for Drop-time walk).
    first_seg: AtomicPtr<Segment<T>>,
    reclaim_lock: Mutex<()>,
}

unsafe impl<T: Send> Send for SegQueue<T> {}
unsafe impl<T: Send> Sync for SegQueue<T> {}

impl<T> SegQueue<T> {
    /// Creates an empty queue with one segment.
    pub fn new() -> Self {
        let seg = Segment::<T>::alloc(0);
        Self {
            head: AtomicUsize::new(0),
            tail: AtomicUsize::new(0),
            head_seg: AtomicPtr::new(seg),
            tail_seg: AtomicPtr::new(seg),
            first_seg: AtomicPtr::new(seg),
            reclaim_lock: Mutex::new(()),
        }
    }

    /// Walks (and extends) the segment chain from `seg` to the segment
    /// containing `ticket`.
    ///
    /// # Safety: `seg` must be a live segment with `(*seg).base <= ticket`.
    unsafe fn seg_for(&self, mut seg: *mut Segment<T>, ticket: usize) -> *mut Segment<T> {
        debug_assert!((*seg).base <= ticket);
        while ticket >= (*seg).base + SEG_CAP {
            let next = (*seg).next.load(Ordering::Acquire);
            let next = if next.is_null() {
                let fresh = Segment::<T>::alloc((*seg).base + SEG_CAP);
                match (*seg).next.compare_exchange(
                    ptr::null_mut(),
                    fresh,
                    Ordering::AcqRel,
                    Ordering::Acquire,
                ) {
                    Ok(_) => fresh,
                    Err(existing) => {
                        // Someone else linked first; free ours.
                        drop(Box::from_raw(fresh));
                        existing
                    }
                }
            } else {
                next
            };
            seg = next;
        }
        seg
    }
}

impl<T> Default for SegQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T: Send> Injector<T> for SegQueue<T> {
    fn push(&self, value: T) {
        let ticket = self.tail.fetch_add(1, Ordering::AcqRel);
        let mut cached = self.tail_seg.load(Ordering::Acquire);
        // The cache may lag (another producer extended the chain before
        // updating it) or even overshoot our ticket (a faster producer
        // advanced it past us) — if it overshot, restart the walk from
        // the first segment, which is never freed before Drop.
        if unsafe { (*cached).base } > ticket {
            cached = self.first_seg.load(Ordering::Acquire);
        }
        let seg = unsafe { self.seg_for(cached, ticket) };
        if seg != cached {
            self.tail_seg.store(seg, Ordering::Release); // best-effort
        }
        unsafe {
            let slot = &(*seg).slots[ticket - (*seg).base];
            ptr::write(slot.value.as_ptr() as *mut T, value);
            slot.ready.store(true, Ordering::Release);
        }
    }

    fn pop(&self) -> Option<T> {
        loop {
            let head = self.head.load(Ordering::Acquire);
            let tail = self.tail.load(Ordering::Acquire);
            if head >= tail {
                return None;
            }
            if self
                .head
                .compare_exchange_weak(head, head + 1, Ordering::AcqRel, Ordering::Acquire)
                .is_err()
            {
                continue;
            }
            let mut cached = self.head_seg.load(Ordering::Acquire);
            if unsafe { (*cached).base } > head {
                // A faster consumer advanced the cache past our ticket.
                cached = self.first_seg.load(Ordering::Acquire);
            }
            let seg = unsafe { self.seg_for(cached, head) };
            if seg != cached {
                self.head_seg.store(seg, Ordering::Release); // best-effort
            }
            unsafe {
                let slot = &(*seg).slots[head - (*seg).base];
                // The producer owns this ticket and is about to set
                // ready; spin briefly (bounded by one producer's write).
                while !slot.ready.load(Ordering::Acquire) {
                    std::hint::spin_loop();
                }
                slot.ready.store(false, Ordering::Relaxed);
                return Some(ptr::read(slot.value.as_ptr()));
            }
        }
    }

    fn is_empty(&self) -> bool {
        let head = self.head.load(Ordering::Acquire);
        let tail = self.tail.load(Ordering::Acquire);
        head >= tail
    }

    fn len(&self) -> usize {
        let head = self.head.load(Ordering::Acquire);
        let tail = self.tail.load(Ordering::Acquire);
        tail.saturating_sub(head)
    }
}

impl<T> Drop for SegQueue<T> {
    fn drop(&mut self) {
        let _g = self.reclaim_lock.lock().unwrap();
        // Drain remaining ready values, then free the whole chain.
        let head = *self.head.get_mut();
        let tail = *self.tail.get_mut();
        let mut seg = self.first_seg.load(Ordering::Relaxed);
        let mut base = 0usize;
        unsafe {
            while !seg.is_null() {
                for i in 0..SEG_CAP {
                    let ticket = base + i;
                    if ticket >= head && ticket < tail {
                        let slot = &(*seg).slots[i];
                        if slot.ready.load(Ordering::Relaxed) {
                            drop(ptr::read(slot.value.as_ptr()));
                        }
                    }
                }
                let next = (*seg).next.load(Ordering::Relaxed);
                drop(Box::from_raw(seg));
                seg = next;
                base += SEG_CAP;
            }
        }
    }
}

/// The pool's injection queue split into [`NUM_LANES`] priority lanes
/// (PR 4): one [`Injector`] per lane plus a scan policy.
///
/// * **push** — producers that know a task's priority push to its lane
///   ([`LaneInjector::push_to`] / [`LaneInjector::push_batch_to`]);
///   everything else lands in [`DEFAULT_LANE`].
/// * **pop** — consumers (workers stealing from the injector, assist
///   helpers) scan lane 0 → N-1, so cross-thread submission and
///   injector-side stealing both prefer critical work. Every
///   [`STARVATION_TICK`]-th pop scans N-1 → 0 instead, bounding how
///   long a loaded high lane can starve the low lanes.
///
/// Within a lane each sub-injector keeps its own FIFO order, so with
/// every producer using one lane (priority lanes disabled) the
/// structure degenerates to exactly the old single-queue behaviour —
/// the other lanes cost one emptiness-flag load per pop.
pub struct LaneInjector<T> {
    lanes: Vec<Box<dyn Injector<T>>>,
    /// Pop tick driving the occasional reverse scan — **per-injector**
    /// state (PR 5). It used to be a process-wide thread-local shared
    /// by every `LaneInjector`, which let unrelated pools (and now
    /// unrelated shards of one pool) advance each other's tick: one
    /// injector could reverse-scan twice in a row while its neighbour
    /// never did, voiding the per-queue starvation bound. A relaxed
    /// per-injector counter restores the bound exactly — every
    /// [`STARVATION_TICK`]-th *pop of this injector* scans reversed —
    /// and the RMW it costs sits on a path that already takes a lock
    /// (`MutexInjector`) or a CAS (`SegQueue`) per pop; the empty fast
    /// path below never touches it. Cache-padded so the hot tick line
    /// is not shared with the read-only lane pointers.
    tick: CachePadded<AtomicUsize>,
}

impl<T: Send> LaneInjector<T> {
    /// Builds [`NUM_LANES`] lanes from the given sub-injector factory.
    pub fn new(mk: impl Fn() -> Box<dyn Injector<T>>) -> Self {
        Self {
            lanes: (0..NUM_LANES).map(|_| mk()).collect(),
            tick: CachePadded::new(AtomicUsize::new(0)),
        }
    }

    /// Enqueues into `lane` (clamped to the valid range).
    pub fn push_to(&self, lane: u8, value: T) {
        self.lanes[(lane as usize).min(NUM_LANES - 1)].push(value);
    }

    /// Enqueues into [`DEFAULT_LANE`] (untagged submissions).
    pub fn push(&self, value: T) {
        self.push_to(DEFAULT_LANE, value);
    }

    /// Enqueues a burst into `lane`, paying the lane's per-burst
    /// synchronization cost once (see [`Injector::push_batch`]).
    pub fn push_batch_to(&self, lane: u8, values: &mut dyn Iterator<Item = T>) {
        self.lanes[(lane as usize).min(NUM_LANES - 1)].push_batch(values);
    }

    /// Dequeues the most urgent available task (see the scan policy in
    /// the type docs).
    pub fn pop(&self) -> Option<T> {
        // Empty fast path first: idle workers poll the injector on
        // every find-task sweep, and that path must stay load-only
        // (four emptiness-flag loads, no tick bookkeeping).
        if self.is_empty() {
            return None;
        }
        // The tick advances only when work may be taken, which is
        // exactly when the starvation bound matters.
        let tick = self.tick.fetch_add(1, Ordering::Relaxed).wrapping_add(1);
        if tick % STARVATION_TICK == 0 {
            self.lanes.iter().rev().find_map(|lane| lane.pop())
        } else {
            self.lanes.iter().find_map(|lane| lane.pop())
        }
    }

    /// Approximate emptiness across all lanes (same staleness caveats
    /// as [`Injector::is_empty`]).
    pub fn is_empty(&self) -> bool {
        self.lanes.iter().all(|l| l.is_empty())
    }

    /// Approximate total length across all lanes.
    pub fn len(&self) -> usize {
        self.lanes.iter().map(|l| l.len()).sum()
    }

    /// Approximate length of one lane (clamped index). Feeds the
    /// per-shard depth snapshot in `ThreadPool::metrics()` (PR 5).
    pub fn lane_len(&self, lane: usize) -> usize {
        self.lanes[lane.min(NUM_LANES - 1)].len()
    }

    /// Approximate per-lane lengths, one probe per lane.
    pub fn lane_depths(&self) -> [usize; NUM_LANES] {
        let mut depths = [0usize; NUM_LANES];
        for (d, l) in depths.iter_mut().zip(&self.lanes) {
            *d = l.len();
        }
        depths
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn fifo_smoke(q: &dyn Injector<usize>) {
        assert!(q.is_empty());
        for i in 0..200 {
            q.push(i);
        }
        assert_eq!(q.len(), 200);
        for i in 0..200 {
            assert_eq!(q.pop(), Some(i));
        }
        assert_eq!(q.pop(), None);
        assert!(q.is_empty());
    }

    #[test]
    fn mutex_injector_fifo() {
        fifo_smoke(&MutexInjector::new());
    }

    #[test]
    fn push_batch_preserves_fifo_on_both_impls() {
        let queues: [Box<dyn Injector<usize>>; 2] =
            [Box::new(MutexInjector::new()), Box::new(SegQueue::new())];
        for q in &queues {
            q.push(0);
            q.push_batch(&mut (1..100usize));
            assert_eq!(q.len(), 100);
            for i in 0..100 {
                assert_eq!(q.pop(), Some(i));
            }
            assert!(q.is_empty());
            // An empty batch is a no-op.
            q.push_batch(&mut std::iter::empty());
            assert!(q.is_empty());
        }
    }

    #[test]
    fn seg_queue_fifo_across_segments() {
        fifo_smoke(&SegQueue::new());
    }

    fn mpmc_stress(q: Arc<dyn Injector<usize>>) {
        const PRODUCERS: usize = 2;
        const CONSUMERS: usize = 2;
        const PER: usize = 5_000;
        let seen = Arc::new(
            (0..PRODUCERS * PER)
                .map(|_| std::sync::atomic::AtomicUsize::new(0))
                .collect::<Vec<_>>(),
        );
        let consumed = Arc::new(std::sync::atomic::AtomicUsize::new(0));
        let mut handles = Vec::new();
        for p in 0..PRODUCERS {
            let q = q.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..PER {
                    q.push(p * PER + i);
                }
            }));
        }
        for _ in 0..CONSUMERS {
            let q = q.clone();
            let seen = seen.clone();
            let consumed = consumed.clone();
            handles.push(std::thread::spawn(move || {
                while consumed.load(Ordering::Acquire) < PRODUCERS * PER {
                    if let Some(v) = q.pop() {
                        seen[v].fetch_add(1, Ordering::Relaxed);
                        consumed.fetch_add(1, Ordering::AcqRel);
                    } else {
                        std::thread::yield_now();
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!(seen.iter().all(|c| c.load(Ordering::Relaxed) == 1));
        assert!(q.is_empty());
    }

    #[test]
    fn mutex_injector_mpmc() {
        mpmc_stress(Arc::new(MutexInjector::new()));
    }

    #[test]
    fn seg_queue_mpmc() {
        mpmc_stress(Arc::new(SegQueue::new()));
    }

    fn lane_injector() -> LaneInjector<usize> {
        LaneInjector::new(|| Box::new(MutexInjector::new()))
    }

    #[test]
    fn lanes_pop_highest_priority_first() {
        let q = lane_injector();
        q.push_to(3, 30);
        q.push_to(0, 0);
        q.push_to(2, 20);
        q.push_to(0, 1);
        q.push_to(1, 10);
        assert_eq!(q.len(), 5);
        // Forward scans: lane 0 FIFO, then lane 1, 2, 3.
        assert_eq!(q.pop(), Some(0));
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(10));
        assert_eq!(q.pop(), Some(20));
        assert_eq!(q.pop(), Some(30));
        assert_eq!(q.pop(), None);
        assert!(q.is_empty());
    }

    #[test]
    fn lanes_default_push_goes_to_default_lane() {
        let q = lane_injector();
        q.push(7);
        q.push_to(DEFAULT_LANE + 1, 8);
        q.push_to(0, 6);
        assert_eq!(q.pop(), Some(6));
        assert_eq!(q.pop(), Some(7));
        assert_eq!(q.pop(), Some(8));
    }

    #[test]
    fn lanes_batch_push_preserves_fifo_within_lane() {
        let q = lane_injector();
        q.push_batch_to(2, &mut (0..50usize));
        q.push_batch_to(1, &mut (100..110usize));
        for i in 100..110 {
            assert_eq!(q.pop(), Some(i));
        }
        for i in 0..50 {
            assert_eq!(q.pop(), Some(i));
        }
        assert!(q.is_empty());
    }

    #[test]
    fn lanes_out_of_range_lane_is_clamped() {
        let q = lane_injector();
        q.push_to(200, 1);
        q.push_to(NUM_LANES as u8 - 1, 0);
        assert_eq!(q.len(), 2);
        // Both landed in the last lane, FIFO within it.
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(0));
    }

    #[test]
    fn lanes_starvation_tick_eventually_pops_low_lane() {
        // With lane 0 always loaded, the reverse scan must still reach
        // lane 3 within STARVATION_TICK pops.
        let q = lane_injector();
        q.push_to(3, usize::MAX);
        let mut popped_low = false;
        for i in 0..200 {
            q.push_to(0, i);
            match q.pop() {
                Some(usize::MAX) => {
                    popped_low = true;
                    break;
                }
                Some(_) => {}
                None => unreachable!("lane 0 was just pushed"),
            }
        }
        assert!(popped_low, "low lane starved past the starvation bound");
    }

    #[test]
    fn starvation_tick_is_per_injector() {
        // Two injectors popped in lockstep: each must fire its reverse
        // scan on ITS OWN 61st pop. With the old process-wide
        // thread-local tick, q2's pops advanced q1's cadence and the
        // sentinel would surface after ~30 q1-pops instead of 61.
        let q1 = lane_injector();
        let q2 = lane_injector();
        q1.push_to(3, usize::MAX);
        let mut q1_pops = 0usize;
        loop {
            q1.push_to(0, q1_pops);
            q2.push_to(0, q1_pops);
            let got = q1.pop().expect("lane 0 was just pushed");
            let _ = q2.pop().expect("lane 0 was just pushed");
            q1_pops += 1;
            if got == usize::MAX {
                break;
            }
            assert!(q1_pops <= 200, "low lane starved past the bound");
        }
        // The reverse scan fires exactly on q1's own STARVATION_TICK-th
        // pop, independent of q2's identical traffic.
        assert_eq!(q1_pops, STARVATION_TICK);
    }

    #[test]
    fn lane_depths_track_per_lane_lengths() {
        let q = lane_injector();
        q.push_to(0, 1);
        q.push_to(2, 2);
        q.push_to(2, 3);
        assert_eq!(q.lane_depths(), [1, 0, 2, 0]);
        assert_eq!(q.lane_len(2), 2);
        assert_eq!(q.lane_len(200), 0); // clamped to the last lane
        assert_eq!(q.len(), 3);
    }

    #[test]
    fn seg_queue_drop_releases_values() {
        static DROPS: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(0);
        struct D;
        impl Drop for D {
            fn drop(&mut self) {
                DROPS.fetch_add(1, Ordering::Relaxed);
            }
        }
        {
            let q = SegQueue::new();
            for _ in 0..100 {
                q.push(D);
            }
            drop(q.pop());
        }
        assert_eq!(DROPS.load(Ordering::Relaxed), 100);
    }
}
