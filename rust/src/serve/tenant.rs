//! Tenant registry types (PR 7): who is allowed to submit, with what
//! weight, onto which slice of the pool.
//!
//! A tenant is the serving tier's unit of isolation. Its [`TenantSpec`]
//! maps service-level intent onto the scheduler features of earlier
//! PRs: the DRR `weight` divides dispatch grants under contention, the
//! `class` rides PR 4's priority lanes (and PR 6's Low-shed-first
//! overload policy), the `shard` pin rides PR 5's locality routing, and
//! `max_inflight` caps the tenant *before* the pool-wide PR 6 budget —
//! so a storming tenant exhausts its own cap, not the pool.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::time::Duration;

use crate::graph::RunPriority;
use crate::obs::{Histogram, HIST_MIN_SAMPLES};
use crate::pool::TenantSnapshot;

/// Opaque handle to a registered tenant, returned by
/// [`crate::serve::GraphService::register_tenant`]. Indexes the
/// service's registry; cheap to copy into every request site.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct TenantId(pub(crate) usize);

impl TenantId {
    /// Registry index of this tenant (matches
    /// [`TenantSnapshot::id`]).
    pub fn index(self) -> usize {
        self.0
    }
}

/// Static configuration of one tenant. Built with the fluent setters;
/// the defaults describe a modest, well-behaved tenant.
#[derive(Debug, Clone)]
pub struct TenantSpec {
    /// Human-readable name (diagnostics and snapshots only).
    pub name: String,
    /// Deficit-round-robin weight: under contention, dispatch grants
    /// divide proportionally to weight. Clamped to at least 1.
    pub weight: u32,
    /// Run class for every launch of this tenant (PR 4 lanes; `Low`
    /// additionally opts into PR 6 / brownout shed-first policy).
    pub class: RunPriority,
    /// Shard pin for every launch (PR 5 locality routing); `None`
    /// routes through the pool's default striping.
    pub shard: Option<usize>,
    /// Maximum runs of this tenant in flight at once — the per-tenant
    /// cap enforced by the service gate before the pool-wide budget.
    /// Clamped to at least 1.
    pub max_inflight: usize,
    /// Default deadline applied to every request (measured from
    /// arrival at the service), unless the request overrides it.
    /// `None` = no deadline.
    pub deadline: Option<Duration>,
}

impl TenantSpec {
    /// A weight-1, Normal-class, unpinned tenant with 4 inflight slots
    /// and no deadline.
    pub fn new(name: impl Into<String>) -> Self {
        Self {
            name: name.into(),
            weight: 1,
            class: RunPriority::Normal,
            shard: None,
            max_inflight: 4,
            deadline: None,
        }
    }

    /// Sets the DRR weight (clamped to ≥ 1).
    pub fn weight(mut self, weight: u32) -> Self {
        self.weight = weight.max(1);
        self
    }

    /// Sets the run class.
    pub fn class(mut self, class: RunPriority) -> Self {
        self.class = class;
        self
    }

    /// Pins every launch to one pool shard.
    pub fn shard(mut self, shard: usize) -> Self {
        self.shard = Some(shard);
        self
    }

    /// Sets the per-tenant inflight cap (clamped to ≥ 1).
    pub fn max_inflight(mut self, cap: usize) -> Self {
        self.max_inflight = cap.max(1);
        self
    }

    /// Sets the default per-request deadline.
    pub fn deadline(mut self, deadline: Duration) -> Self {
        self.deadline = Some(deadline);
        self
    }
}

/// Runtime state of one tenant: the spec plus lifecycle counters. The
/// counters are relaxed atomics — they are read by snapshots and
/// tests, never used for control decisions (those happen under the
/// service gate lock, where `inflight` is written).
#[derive(Debug)]
pub(crate) struct TenantState {
    pub(crate) spec: TenantSpec,
    /// Requests granted and not yet completed. Written under the gate
    /// lock (grant) and on the completion path (release).
    pub(crate) inflight: AtomicUsize,
    pub(crate) submitted: AtomicU64,
    pub(crate) completed: AtomicU64,
    pub(crate) retries: AtomicU64,
    pub(crate) shed_low: AtomicU64,
    pub(crate) shed_over_quota: AtomicU64,
    pub(crate) shed_deadline: AtomicU64,
    pub(crate) failed: AtomicU64,
    /// Per-tenant service-time EWMA in nanoseconds (PR 8): grant →
    /// successful completion, α = 1/8; 0 = no completions yet. This is
    /// the tenant's own latency signal — the gate uses it alongside
    /// the pool-wide queue-delay EWMA for deadline feasibility, and
    /// the launch path uses it to demote chronically slow tenants off
    /// the High lanes (see `serve/service.rs`).
    pub(crate) service_ewma_ns: AtomicU64,
    /// Launches demoted off the tenant's declared class because its
    /// service EWMA exceeded [`crate::serve::ServiceConfig::demote_slow_after`].
    pub(crate) demotions: AtomicU64,
    /// Per-tenant grant→completion latency histogram (PR 9): the
    /// distribution behind `service_ewma_ns`. Once it holds
    /// [`HIST_MIN_SAMPLES`] completions its p99 supersedes the EWMA in
    /// the gate's feasibility check and the launch path's slow-tenant
    /// demotion — a tail estimate, which is what those SLO decisions
    /// actually compare against. Exported per tenant on the metrics
    /// listener and the STATS v2 frame.
    pub(crate) latency: Histogram,
}

impl TenantState {
    pub(crate) fn new(spec: TenantSpec) -> Self {
        Self {
            spec,
            inflight: AtomicUsize::new(0),
            submitted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            retries: AtomicU64::new(0),
            shed_low: AtomicU64::new(0),
            shed_over_quota: AtomicU64::new(0),
            shed_deadline: AtomicU64::new(0),
            failed: AtomicU64::new(0),
            service_ewma_ns: AtomicU64::new(0),
            demotions: AtomicU64::new(0),
            latency: Histogram::new(),
        }
    }

    /// Folds one grant→completion latency into the service-time EWMA
    /// (first sample seeds; stored value floors at 1 ns so "has
    /// completed" is distinguishable from "never completed") and into
    /// the tenant's latency histogram (PR 9).
    pub(crate) fn note_service_time(&self, took: Duration) {
        let sample = took.as_nanos() as u64;
        let cur = self.service_ewma_ns.load(Ordering::Relaxed);
        let next = if cur == 0 { sample } else { cur - cur / 8 + sample / 8 };
        self.service_ewma_ns.store(next.max(1), Ordering::Relaxed);
        self.latency.record(sample);
    }

    /// Current service-time EWMA (zero until the first completion).
    pub(crate) fn service_ewma(&self) -> Duration {
        Duration::from_nanos(self.service_ewma_ns.load(Ordering::Relaxed))
    }

    /// The tenant's tail (p99) service time once the latency histogram
    /// is warm ([`HIST_MIN_SAMPLES`] completions); `None` during cold
    /// start, when callers should fall back to [`TenantState::service_ewma`].
    pub(crate) fn service_p99(&self) -> Option<Duration> {
        (self.latency.count() >= HIST_MIN_SAMPLES)
            .then(|| Duration::from_nanos(self.latency.snapshot().quantile(0.99)))
    }

    /// The tail-aware service estimate the SLO checks compare against:
    /// histogram p99 when warm, EWMA otherwise (zero until the first
    /// completion).
    pub(crate) fn service_estimate(&self) -> Duration {
        self.service_p99().unwrap_or_else(|| self.service_ewma())
    }

    pub(crate) fn snapshot(&self, id: usize) -> TenantSnapshot {
        TenantSnapshot {
            id,
            name: self.spec.name.clone(),
            weight: self.spec.weight,
            inflight: self.inflight.load(Ordering::Relaxed),
            submitted: self.submitted.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            retries: self.retries.load(Ordering::Relaxed),
            shed_low: self.shed_low.load(Ordering::Relaxed),
            shed_over_quota: self.shed_over_quota.load(Ordering::Relaxed),
            shed_deadline: self.shed_deadline.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            service_ewma_ns: self.service_ewma_ns.load(Ordering::Relaxed),
            demotions: self.demotions.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_builder_clamps_and_sets() {
        let s = TenantSpec::new("gold")
            .weight(0)
            .class(RunPriority::High)
            .shard(3)
            .max_inflight(0)
            .deadline(Duration::from_millis(5));
        assert_eq!(s.weight, 1, "weight clamps to 1");
        assert_eq!(s.max_inflight, 1, "cap clamps to 1");
        assert_eq!(s.shard, Some(3));
        assert!(matches!(s.class, RunPriority::High));
        assert_eq!(s.deadline, Some(Duration::from_millis(5)));
    }

    #[test]
    fn snapshot_reflects_counters() {
        let t = TenantState::new(TenantSpec::new("x").weight(7));
        t.submitted.fetch_add(3, Ordering::Relaxed);
        t.completed.fetch_add(2, Ordering::Relaxed);
        t.shed_low.fetch_add(1, Ordering::Relaxed);
        let s = t.snapshot(4);
        assert_eq!((s.id, s.weight, s.submitted, s.completed), (4, 7, 3, 2));
        assert_eq!(s.shed_total(), 1);
    }
}
