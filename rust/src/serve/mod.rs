//! Graph-as-a-service: the in-process serving front-end (PR 7).
//!
//! Everything below `serve/` turns the pool + graph executor into
//! something that can face sustained, adversarial traffic: many
//! concurrent clients, tenants with very different importance, storms,
//! transient overload, and deadline-carrying requests. The pieces:
//!
//! * [`GraphService`] (`service.rs`) — the front-end. Clients call
//!   [`GraphService::run`] from any number of threads; each request is
//!   parked in a per-tenant dispatch queue and granted in
//!   **deficit-round-robin** order weighted by tenant, so one tenant's
//!   storm cannot starve another. Granted requests launch on the pool
//!   with the tenant's PR-4 run class and PR-5 shard pin, under the
//!   tenant's own inflight cap — enforced *before* the pool-wide PR-6
//!   admission budget ever sees the run.
//! * [`TenantSpec`] / [`TenantId`] (`tenant.rs`) — the tenant registry:
//!   DRR weight, run class, shard pin, inflight cap, default deadline.
//! * [`RetryPolicy`] (`retry.rs`) — retry with exponential backoff and
//!   jitter for `Overloaded` / `DeadlineExceeded` outcomes, bounded by
//!   a **retry budget** replenished as a fraction of goodput so retries
//!   can never amplify an overload. Backoff timers park on the
//!   `pool/timer.rs` min-heap thread.
//! * [`BrownoutController`] (`brownout.rs`) — graceful degradation: a
//!   queue-delay EWMA drives a small state machine that sheds work in
//!   documented order (Low-class tenants first, then over-quota
//!   backlogs, while deadline-infeasible requests are always rejected
//!   at admission) and recovers hysteretically.
//!
//! # Request lifecycle
//!
//! ```text
//! client thread                    service gate                 pool
//! ------------- enqueue ---------> per-tenant DRR queue
//!      (parks on its ticket)          | pump(): weighted grants,
//!                                     | brownout sheds, deadline
//!                                     | feasibility
//! <------------ grant/shed ----------'
//!   grant: queue-delay sample -> pool EWMA + brownout
//!   try_run(class, shard, remaining deadline) ----------------> run
//! <------------------- Ok | Overloaded | DeadlineExceeded | ... ----
//!   Ok        -> goodput, retry budget refill
//!   retryable -> backoff timer (pool/timer.rs) -> re-enqueue
//!   otherwise -> ServeError::Failed
//! ```
//!
//! The service core stays in-process; PR 8 adds the promised network
//! skin on top:
//!
//! * [`WireServer`] / [`WireClient`] (`wire.rs`) — a std-only TCP
//!   front-end speaking length-prefixed frames that name a
//!   pre-registered graph template, a tenant token, and an optional
//!   deadline. Requests launch through the untouched [`GraphService`]
//!   gate, and a plaintext scrape endpoint exports the tenant /
//!   brownout / retry / re-rank counters. The `graph_serve` binary
//!   (`rust/src/bin/graph_serve.rs`) wraps it into a standalone server
//!   and client CLI.
//!
//! PR 8 also teaches admission two latency-feedback tricks: each
//! tenant carries a grant→completion **service-time EWMA**, used both
//! as a deadline-feasibility floor at the gate (a request whose
//! remaining budget is below the tenant's own typical service time is
//! rejected before queueing) and to **demote chronically slow
//! tenants** off the High priority lanes
//! ([`ServiceConfig::demote_slow_after`]).

mod brownout;
mod retry;
mod service;
mod tenant;
mod wire;

pub use brownout::{BrownoutConfig, BrownoutController, BrownoutLevel};
pub use retry::RetryPolicy;
pub use service::{GraphService, ServeError, ServiceConfig, ShedReason};
pub use tenant::{TenantId, TenantSpec};
pub use wire::{
    wire_run, wire_scrape, WireClient, WireHandle, WireServer, WireStatus, MAX_FRAME, WIRE_VERSION,
};
