//! The graph-serving front-end (PR 7): tenant-fair admission, retry
//! with a bounded budget, and brownout shedding, in front of the pool.
//!
//! # Design: an admission gate, not a dispatcher
//!
//! [`crate::graph::RunHandle`] borrows its graph (`RunHandle<'g>`), so
//! a queue of *graphs* owned by a dispatcher thread is impossible
//! without giving up the zero-copy borrow model. Instead the service
//! queues **callers**: each [`GraphService::run`] parks its thread on a
//! ticket in a per-tenant FIFO; a pump (run under the gate lock by
//! whichever thread last changed state — enqueue or completion) grants
//! tickets in deficit-round-robin order, and the granted caller then
//! launches its own graph on the pool. The graph never changes hands,
//! so everything from PR 2's zero-alloc re-runs to PR 6's lifecycle
//! keeps working unchanged underneath the service.
//!
//! Admission is layered, cheapest rejection first:
//!
//! 1. **Deadline feasibility** — a request is rejected with
//!    [`GraphError::WouldMissDeadline`] before holding any slot when
//!    its deadline has already passed (checked unconditionally, even
//!    on a cold gate), or its remaining deadline is ≤ the gate-delay
//!    estimate, or ≤ the *tenant's own* service estimate (PR 8 — a
//!    tenant whose graphs take 40 ms cannot make a 5 ms deadline no
//!    matter how idle the gate is). Both estimates are tail-aware
//!    (PR 9): the p99 of the gate-wait / tenant-latency histograms
//!    once they hold [`crate::obs::HIST_MIN_SAMPLES`] samples, the
//!    corresponding EWMA during cold start.
//! 2. **Brownout shedding** — at [`BrownoutLevel::ShedLow`] the gate
//!    sheds Low-class tenants' queues; at
//!    [`BrownoutLevel::ShedOverQuota`] also the queues of tenants
//!    holding ≥ their weight-proportional share of inflight slots.
//! 3. **DRR grant** — remaining queued tickets are granted in
//!    weight-proportional order, bounded by each tenant's
//!    `max_inflight` and the service-wide [`ServiceConfig::max_inflight`].
//! 4. **Pool budget** — the launch itself uses the non-blocking
//!    [`crate::graph::TaskGraph::try_run_with_options`] path, so PR 6's
//!    pool-wide budget stays the final authority; its `Overloaded` is
//!    what the retry machinery absorbs.

use std::collections::VecDeque;
use std::fmt;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::graph::{
    chaos_inject_launch_panic, chaos_inject_overload, GraphError, RunOptions, RunPriority,
    TaskGraph,
};
use crate::obs::{EventKind, Histogram, HistogramSnapshot, HIST_MIN_SAMPLES};
use crate::pool::{TenantSnapshot, ThreadPool};
use crate::util::XorShift64Star;

use super::brownout::{BrownoutConfig, BrownoutController, BrownoutLevel};
use super::retry::{RetryBudget, RetryPolicy};
use super::tenant::{TenantId, TenantSpec, TenantState};

/// Why the gate refused a request without launching it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedReason {
    /// Brownout at [`BrownoutLevel::ShedLow`] or worse and the tenant's
    /// class is `Low`.
    Low,
    /// Brownout at [`BrownoutLevel::ShedOverQuota`] and the tenant held
    /// at least its weight-proportional share of inflight slots.
    OverQuota,
}

impl fmt::Display for ShedReason {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Self::Low => write!(f, "brownout shed (low-class tenant)"),
            Self::OverQuota => write!(f, "brownout shed (tenant over its inflight quota)"),
        }
    }
}

/// Terminal outcome of a [`GraphService::run`] request.
#[derive(Debug)]
pub enum ServeError {
    /// The [`TenantId`] was not issued by this service.
    UnknownTenant,
    /// The brownout controller shed the request at the gate; the graph
    /// was never launched.
    Shed(ShedReason),
    /// The run failed with a non-retryable error (including
    /// [`GraphError::WouldMissDeadline`] from the feasibility check).
    Failed(GraphError),
    /// Every allowed attempt failed with a retryable error (or the
    /// retry budget ran dry first). `last` is the final attempt's
    /// error.
    RetriesExhausted {
        /// Launch attempts actually made (≥ 1).
        attempts: u32,
        /// Error of the last attempt.
        last: GraphError,
    },
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Self::UnknownTenant => write!(f, "tenant id was not issued by this service"),
            Self::Shed(r) => write!(f, "request shed at admission: {r}"),
            Self::Failed(e) => write!(f, "run failed: {e}"),
            Self::RetriesExhausted { attempts, last } => {
                write!(f, "retries exhausted after {attempts} attempts; last error: {last}")
            }
        }
    }
}

impl std::error::Error for ServeError {}

/// Service-wide knobs. Per-tenant knobs live in [`TenantSpec`].
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Total requests granted (launched or launching) at once across
    /// all tenants — the service's own concurrency ceiling, enforced
    /// before the pool-wide PR 6 budget. Clamped to ≥ 1.
    pub max_inflight: usize,
    /// Retry schedule and budget for `Overloaded` /
    /// `DeadlineExceeded` outcomes.
    pub retry: RetryPolicy,
    /// Brownout thresholds and hysteresis.
    pub brownout: BrownoutConfig,
    /// Slow-tenant demotion threshold (PR 8): once a tenant's
    /// service-time EWMA (grant → successful completion) exceeds this,
    /// its `High`-class launches are demoted to `Normal` and, when the
    /// tenant has no shard pin, routed onto the pool's last shard (the
    /// "quarantine shard") — chronically slow work stops occupying the
    /// express lanes and stops polluting every cache domain. `None`
    /// disables demotion. The tenant's declared class is untouched;
    /// the EWMA recovering below the threshold restores it.
    pub demote_slow_after: Option<Duration>,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            max_inflight: 32,
            retry: RetryPolicy::default(),
            brownout: BrownoutConfig::default(),
            demote_slow_after: Some(Duration::from_millis(50)),
        }
    }
}

/// Ticket states. `WAITING → GRANTED | SHED_* | INFEASIBLE`, written
/// only by the pump (under the gate lock), read by the parked caller.
const WAITING: u8 = 0;
const GRANTED: u8 = 1;
const SHED_LOW: u8 = 2;
const SHED_OVER_QUOTA: u8 = 3;
const INFEASIBLE: u8 = 4;

/// One parked request: the caller thread waits on the gate condvar
/// until the pump resolves its ticket.
struct Ticket {
    state: AtomicU8,
    enqueued: Instant,
    deadline_at: Option<Instant>,
}

/// Everything the DRR pump mutates, under one mutex. `queues[i]`,
/// `deficits[i]` and `tenants[i]` are parallel arrays indexed by
/// [`TenantId`].
struct GateState {
    tenants: Vec<Arc<TenantState>>,
    queues: Vec<VecDeque<Arc<Ticket>>>,
    /// DRR deficit counters, in milli-grants (one grant costs
    /// [`DRR_COST`]).
    deficits: Vec<u64>,
    /// Round-robin position of the pump across tenants.
    cursor: usize,
    /// Requests granted and not yet finished, service-wide.
    inflight: usize,
}

/// DRR cost of one grant; a tenant's per-visit deposit is
/// `weight × DRR_COST`, so weights divide grants proportionally.
const DRR_COST: u64 = 1000;
/// Deficit cap in multiples of a tenant's per-visit deposit — bounds
/// how large a burst an idle-then-capped tenant can bank.
const DRR_BURST: u64 = 8;

/// Multi-tenant serving front-end over one [`ThreadPool`]. See the
/// [module docs](self) for the admission pipeline and
/// [`crate::serve`] for the whole serving tier.
///
/// The service is `Sync`: any number of client threads call
/// [`GraphService::run`] concurrently, each bringing its own
/// [`TaskGraph`].
pub struct GraphService {
    pool: ThreadPool,
    cfg: ServiceConfig,
    gate: Mutex<GateState>,
    gate_cv: Condvar,
    pub(crate) brownout: BrownoutController,
    budget: RetryBudget,
    /// Gate-wait (enqueue → grant) latency histogram (PR 9): the
    /// distribution behind the brownout EWMA. Once warm
    /// ([`HIST_MIN_SAMPLES`]), its p99 replaces the EWMA in the pump's
    /// deadline-feasibility check — a request's deadline competes with
    /// the *tail* of the gate delay, not its mean. Exported on the
    /// metrics listener and the STATS v2 frame.
    gate_wait: Histogram,
}

impl GraphService {
    /// Wraps `pool` in a serving front-end. The pool is owned by the
    /// service ([`GraphService::pool`] lends it back for direct use —
    /// runs launched directly on the pool simply bypass the gate).
    pub fn new(pool: ThreadPool, cfg: ServiceConfig) -> Self {
        let mut brownout = BrownoutController::new(cfg.brownout.clone());
        // PR 9: brownout level transitions land in the pool's flight
        // recorder, timestamped on the same epoch as the scheduler
        // events they explain.
        brownout.attach_flight(pool.flight_recorder());
        let budget = RetryBudget::new(&cfg.retry);
        Self {
            pool,
            cfg: ServiceConfig {
                max_inflight: cfg.max_inflight.max(1),
                ..cfg
            },
            gate: Mutex::new(GateState {
                tenants: Vec::new(),
                queues: Vec::new(),
                deficits: Vec::new(),
                cursor: 0,
                inflight: 0,
            }),
            gate_cv: Condvar::new(),
            brownout,
            budget,
            gate_wait: Histogram::new(),
        }
    }

    /// The pool behind the service.
    pub fn pool(&self) -> &ThreadPool {
        &self.pool
    }

    /// Registers a tenant; the returned [`TenantId`] keys every
    /// subsequent [`GraphService::run`] call. Tenants cannot be
    /// unregistered (a serving roster is static per deployment).
    pub fn register_tenant(&self, spec: TenantSpec) -> TenantId {
        let mut st = self.gate.lock().unwrap();
        st.tenants.push(Arc::new(TenantState::new(spec)));
        st.queues.push(VecDeque::new());
        st.deficits.push(0);
        TenantId(st.tenants.len() - 1)
    }

    /// Per-tenant counter snapshots, in registration order.
    pub fn tenant_snapshots(&self) -> Vec<TenantSnapshot> {
        let st = self.gate.lock().unwrap();
        st.tenants.iter().enumerate().map(|(i, t)| t.snapshot(i)).collect()
    }

    /// Current brownout level (degradation state of the gate).
    pub fn brownout_level(&self) -> BrownoutLevel {
        self.brownout.level()
    }

    /// Queue-delay EWMA observed by the gate (grant latency of
    /// recently admitted requests). Zero until the first grant.
    pub fn queue_delay_ewma(&self) -> Duration {
        self.brownout.ewma()
    }

    /// Snapshot of the gate-wait (enqueue → grant) latency histogram
    /// (PR 9). Empty until the first grant.
    pub fn gate_wait_histogram(&self) -> HistogramSnapshot {
        self.gate_wait.snapshot()
    }

    /// Per-tenant grant→completion latency histograms, in registration
    /// order as `(tenant name, snapshot)` (PR 9) — the distributions
    /// behind [`TenantSnapshot::service_ewma_ns`], exported on the
    /// metrics listener and the STATS v2 frame.
    pub fn tenant_latency_histograms(&self) -> Vec<(String, HistogramSnapshot)> {
        let st = self.gate.lock().unwrap();
        st.tenants.iter().map(|t| (t.spec.name.clone(), t.latency.snapshot())).collect()
    }

    /// The gate-delay estimate the feasibility check compares
    /// deadlines against: gate-wait p99 once the histogram is warm
    /// ([`HIST_MIN_SAMPLES`] grants), the brownout EWMA during cold
    /// start. Zero until the first grant.
    pub fn gate_delay_estimate(&self) -> Duration {
        if self.gate_wait.count() >= HIST_MIN_SAMPLES {
            Duration::from_nanos(self.gate_wait.snapshot().quantile(0.99))
        } else {
            self.brownout.ewma()
        }
    }

    /// Whole retry-budget tokens currently available. Diagnostics —
    /// the amplification-cap test asserts this drains under permanent
    /// overload.
    pub fn retry_tokens(&self) -> u64 {
        self.budget.tokens()
    }

    /// Runs `graph` on behalf of `tenant` with the tenant's default
    /// deadline, blocking until the run completes, is shed, or fails
    /// terminally. See [`GraphService::run_with`].
    pub fn run(&self, tenant: TenantId, graph: &mut TaskGraph) -> Result<(), ServeError> {
        self.run_with(tenant, graph, None)
    }

    /// [`GraphService::run`] with an explicit per-request deadline
    /// (overriding the tenant default; measured from *arrival at the
    /// service*, so time spent queued and backing off counts against
    /// it).
    ///
    /// The full lifecycle: enqueue → DRR grant (or shed) → launch with
    /// the tenant's class/shard and the remaining deadline → on
    /// retryable failure, exponential-backoff park on the timer thread
    /// and re-enqueue (spending a retry-budget token) → terminal
    /// outcome.
    pub fn run_with(
        &self,
        tenant: TenantId,
        graph: &mut TaskGraph,
        deadline: Option<Duration>,
    ) -> Result<(), ServeError> {
        let state = {
            let st = self.gate.lock().unwrap();
            st.tenants.get(tenant.0).cloned().ok_or(ServeError::UnknownTenant)?
        };
        let arrival = Instant::now();
        let deadline_at = deadline.or(state.spec.deadline).map(|d| arrival + d);
        state.submitted.fetch_add(1, Ordering::Relaxed);

        let mut rng = XorShift64Star::from_entropy();
        let max_attempts = self.cfg.retry.max_attempts.max(1);
        let mut attempt = 0u32;
        loop {
            attempt += 1;
            // --- park at the gate until granted or shed -------------
            match self.await_grant(tenant.0, deadline_at) {
                GRANTED => {}
                SHED_LOW => return Err(ServeError::Shed(ShedReason::Low)),
                SHED_OVER_QUOTA => return Err(ServeError::Shed(ShedReason::OverQuota)),
                _ => {
                    state.failed.fetch_add(1, Ordering::Relaxed);
                    return Err(ServeError::Failed(GraphError::WouldMissDeadline));
                }
            }

            // --- launch (the grant is held until release) -----------
            // The grant is returned by an RAII guard, not a plain call
            // after `launch` (PR 8 bugfix): a panic anywhere in the
            // launch path — a chaos injection, a bug in option
            // plumbing, a poisoned pool mutex — used to leak one
            // service-wide and one tenant inflight slot permanently,
            // silently shrinking `max_inflight` for the life of the
            // process.
            let granted_at = Instant::now();
            let outcome = {
                let _grant = GrantGuard { svc: self, state: &state };
                self.launch(&state, graph, deadline_at)
            };

            let err = match outcome {
                Ok(()) => {
                    state.note_service_time(granted_at.elapsed());
                    state.completed.fetch_add(1, Ordering::Relaxed);
                    self.budget.on_success();
                    return Ok(());
                }
                Err(e) => e,
            };
            if matches!(err, GraphError::WouldMissDeadline) {
                state.shed_deadline.fetch_add(1, Ordering::Relaxed);
                state.failed.fetch_add(1, Ordering::Relaxed);
                return Err(ServeError::Failed(err));
            }
            if !RetryPolicy::retryable(&err) {
                state.failed.fetch_add(1, Ordering::Relaxed);
                return Err(ServeError::Failed(err));
            }
            // A fixed deadline makes further attempts pointless once
            // it has passed.
            let expired = deadline_at.is_some_and(|at| Instant::now() >= at);
            if attempt >= max_attempts || expired || !self.budget.try_take() {
                state.failed.fetch_add(1, Ordering::Relaxed);
                return Err(ServeError::RetriesExhausted { attempts: attempt, last: err });
            }
            state.retries.fetch_add(1, Ordering::Relaxed);
            let backoff = self.cfg.retry.backoff(attempt, rng.next_u64());
            // PR 9: the retry decision is a scheduler event too — a
            // flight dump of an overload episode shows who was backing
            // off, for how long, between the admission verdicts.
            if let Some(f) = self.pool.flight_recorder() {
                f.record_external(
                    EventKind::RetrySched,
                    tenant.0 as u32,
                    backoff.as_nanos() as u64,
                );
            }
            self.backoff_park(backoff);
        }
    }

    /// Enqueues a ticket for `tenant` and parks until the pump
    /// resolves it. Returns the ticket's terminal state.
    fn await_grant(&self, tenant: usize, deadline_at: Option<Instant>) -> u8 {
        let ticket = Arc::new(Ticket {
            state: AtomicU8::new(WAITING),
            enqueued: Instant::now(),
            deadline_at,
        });
        let mut st = self.gate.lock().unwrap();
        st.queues[tenant].push_back(ticket.clone());
        // An enqueue-pump can resolve *other* callers' tickets too —
        // e.g. shed a parked tenant's whole queue after a brownout
        // escalation — so it must notify like the release path does
        // (PR 8 bugfix). Without this, a ticket resolved here stayed
        // parked until some unrelated release happened to pump again;
        // with zero inflight runs, indefinitely.
        if self.pump(&mut st) {
            self.gate_cv.notify_all();
        }
        while ticket.state.load(Ordering::Acquire) == WAITING {
            st = self.gate_cv.wait(st).unwrap();
        }
        drop(st);
        let resolved = ticket.state.load(Ordering::Acquire);
        if resolved == GRANTED {
            // Grant latency is the service's queue-delay signal: it
            // feeds the brownout controller, the pool's
            // `WouldMissDeadline` admission seam, and (PR 9) the
            // gate-wait histogram whose p99 the pump's feasibility
            // check reads once warm.
            let delay = ticket.enqueued.elapsed();
            self.brownout.observe(delay);
            self.pool.note_queue_delay(delay);
            self.gate_wait.record(delay.as_nanos() as u64);
        }
        resolved
    }

    /// One granted launch attempt: chaos injection, slow-tenant
    /// demotion (PR 8), deadline bookkeeping, then the non-blocking
    /// pool run.
    fn launch(
        &self,
        state: &TenantState,
        graph: &mut TaskGraph,
        deadline_at: Option<Instant>,
    ) -> Result<(), GraphError> {
        if chaos_inject_overload() {
            return Err(GraphError::Overloaded);
        }
        if chaos_inject_launch_panic() {
            panic!("chaos: injected launch panic");
        }
        let spec = &state.spec;
        // Slow-tenant demotion (PR 8): a tenant whose own service-time
        // EWMA says its graphs are chronically slow stops riding the
        // High lanes (where it would delay every fast tenant's
        // critical work) and, when unpinned, is routed onto the pool's
        // last shard so its working set stops washing through every
        // cache domain. Keyed off the live service estimate — the
        // tenant's latency-histogram p99 once warm (PR 9), its EWMA
        // during cold start — so a tenant that speeds back up is
        // restored automatically, and a tenant whose *tail* is slow
        // is demoted even when its mean looks healthy.
        let mut class = spec.class;
        let mut shard = spec.shard;
        if let Some(limit) = self.cfg.demote_slow_after {
            if class == RunPriority::High && state.service_estimate() > limit {
                class = RunPriority::Normal;
                if shard.is_none() {
                    shard = Some(self.pool.num_shards().saturating_sub(1));
                }
                state.demotions.fetch_add(1, Ordering::Relaxed);
            }
        }
        let mut opts = RunOptions::new().priority(class);
        if let Some(shard) = shard {
            opts = opts.on_shard(shard);
        }
        if let Some(at) = deadline_at {
            let remaining = at.saturating_duration_since(Instant::now());
            if remaining.is_zero() {
                return Err(GraphError::DeadlineExceeded);
            }
            opts = opts.deadline(remaining);
        }
        graph.try_run_with_options(&self.pool, opts)
    }

    /// Returns a grant: one service slot and one tenant slot, then
    /// re-pumps so a queued ticket can take the freed capacity.
    fn release(&self, state: &TenantState) {
        let mut st = self.gate.lock().unwrap();
        st.inflight = st.inflight.saturating_sub(1);
        state.inflight.fetch_sub(1, Ordering::Relaxed);
        self.pump(&mut st);
        drop(st);
        self.gate_cv.notify_all();
    }

    /// The admission pump: sheds per the brownout level and deadline
    /// feasibility, then grants in DRR order. Runs under the gate lock.
    /// Returns whether any ticket was resolved (granted or shed) —
    /// **every** caller that sees `true` must notify `gate_cv` after
    /// (or while) holding the lock, because the resolved tickets may
    /// belong to other parked callers (PR 8 bugfix; see `await_grant`).
    fn pump(&self, st: &mut GateState) -> bool {
        let level = self.brownout.level();
        // Tail-aware gate-delay estimate (PR 9): p99 of the gate-wait
        // histogram once warm, the brownout EWMA during cold start. A
        // deadline has to clear the tail of the gate delay, not its
        // mean — the EWMA systematically under-rejected under bursty
        // load.
        let delay_est = self.gate_delay_estimate();
        let now = Instant::now();
        let mut resolved = false;

        // --- shed pass ------------------------------------------------
        let total_weight: u64 = st.tenants.iter().map(|t| u64::from(t.spec.weight)).sum();
        let max_inflight = self.cfg.max_inflight;
        let tenants = &st.tenants;
        let queues = &mut st.queues;
        for (i, t) in tenants.iter().enumerate() {
            if queues[i].is_empty() {
                continue;
            }
            // Deadline feasibility applies at every level: work that
            // cannot finish in time must not consume a slot. An
            // already-expired deadline is infeasible *unconditionally*
            // — gating the whole check on a warmed-up EWMA (the
            // pre-PR 8 bug) let a cold gate grant expired requests,
            // which then burned a pool admission slot, failed with
            // `DeadlineExceeded`, and spun through retry backoff on a
            // deadline that could never be met. A nonzero gate-delay
            // estimate (histogram p99 once warm, EWMA before — PR 9)
            // or per-tenant service estimate (p99 of the tenant's
            // latency histogram, its EWMA during cold start)
            // additionally rejects deadlines that are nominally in the
            // future but closer than the work could possibly finish.
            let floor = t.service_estimate();
            queues[i].retain(|ticket| {
                let infeasible = ticket.deadline_at.is_some_and(|at| {
                    let remaining = at.saturating_duration_since(now);
                    remaining.is_zero()
                        || (!delay_est.is_zero() && remaining <= delay_est)
                        || (!floor.is_zero() && remaining <= floor)
                });
                if infeasible {
                    ticket.state.store(INFEASIBLE, Ordering::Release);
                    t.shed_deadline.fetch_add(1, Ordering::Relaxed);
                    resolved = true;
                }
                !infeasible
            });
            if level >= BrownoutLevel::ShedLow && matches!(t.spec.class, RunPriority::Low) {
                for ticket in queues[i].drain(..) {
                    ticket.state.store(SHED_LOW, Ordering::Release);
                    t.shed_low.fetch_add(1, Ordering::Relaxed);
                    resolved = true;
                }
                continue;
            }
            if level >= BrownoutLevel::ShedOverQuota {
                let share = (max_inflight as u64 * u64::from(t.spec.weight)
                    / total_weight.max(1))
                .max(1) as usize;
                if t.inflight.load(Ordering::Relaxed) >= share {
                    for ticket in queues[i].drain(..) {
                        ticket.state.store(SHED_OVER_QUOTA, Ordering::Release);
                        t.shed_over_quota.fetch_add(1, Ordering::Relaxed);
                        resolved = true;
                    }
                }
            }
        }

        // --- grant pass (deficit round-robin) -------------------------
        //
        // Deficits persist across pump invocations and are replenished
        // only when a full sweep finds no grantable deficit (the start
        // of a new DRR round). That detail matters: grants usually
        // trickle out one slot at a time (each completion re-pumps), and
        // depositing on every visit would let every tenant afford every
        // grant, collapsing weighted DRR into unweighted round-robin.
        // With per-round deposits, a weight-3 tenant banks 3 grants per
        // round to a weight-1 tenant's 1, no matter how the grants are
        // spread over pump invocations.
        let n = st.tenants.len();
        if n == 0 {
            return resolved;
        }
        'grants: while st.inflight < self.cfg.max_inflight {
            let mut granted_any = false;
            for _ in 0..n {
                let i = st.cursor % n;
                if st.queues[i].is_empty() {
                    // Classic DRR: an empty queue forfeits its deficit,
                    // so idle tenants cannot bank credit for bursts.
                    st.deficits[i] = 0;
                    st.cursor = (st.cursor + 1) % n;
                    continue;
                }
                let cap = st.tenants[i].spec.max_inflight;
                while st.deficits[i] >= DRR_COST
                    && !st.queues[i].is_empty()
                    && st.tenants[i].inflight.load(Ordering::Relaxed) < cap
                {
                    if st.inflight >= self.cfg.max_inflight {
                        break 'grants;
                    }
                    let ticket = st.queues[i].pop_front().unwrap();
                    ticket.state.store(GRANTED, Ordering::Release);
                    st.tenants[i].inflight.fetch_add(1, Ordering::Relaxed);
                    st.inflight += 1;
                    st.deficits[i] -= DRR_COST;
                    granted_any = true;
                    resolved = true;
                }
                st.cursor = (st.cursor + 1) % n;
            }
            if !granted_any {
                // New round: replenish every tenant that could actually
                // use a grant (backlogged and below its inflight cap).
                // If none qualifies, nothing can be granted right now.
                let mut any_eligible = false;
                for i in 0..n {
                    if st.queues[i].is_empty()
                        || st.tenants[i].inflight.load(Ordering::Relaxed)
                            >= st.tenants[i].spec.max_inflight
                    {
                        continue;
                    }
                    let deposit = u64::from(st.tenants[i].spec.weight) * DRR_COST;
                    st.deficits[i] = (st.deficits[i] + deposit).min(deposit * DRR_BURST);
                    any_eligible = true;
                }
                if !any_eligible {
                    break;
                }
            }
        }
        resolved
    }

    /// Parks the calling thread for `delay` using the pool's timer
    /// thread: one min-heap entry wakes one condvar, so a crowd of
    /// backing-off requests costs heap entries, not spinning threads.
    fn backoff_park(&self, delay: Duration) {
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        let fire = gate.clone();
        crate::pool::timer::schedule_after(
            delay,
            Box::new(move || {
                let (lock, cv) = &*fire;
                *lock.lock().unwrap() = true;
                cv.notify_all();
            }),
        );
        let (lock, cv) = &*gate;
        let mut fired = lock.lock().unwrap();
        while !*fired {
            fired = cv.wait(fired).unwrap();
        }
    }
}

/// RAII return of a dispatch grant (PR 8): constructed the moment a
/// ticket is granted, dropped when the launch attempt finishes —
/// normally *or by unwinding*. Panics in the launch path therefore
/// give back their service-wide and tenant inflight slots (and re-pump
/// the gate) instead of leaking them; the chaos launch-panic test
/// proves it.
struct GrantGuard<'a> {
    svc: &'a GraphService,
    state: &'a TenantState,
}

impl Drop for GrantGuard<'_> {
    fn drop(&mut self) {
        self.svc.release(self.state);
    }
}

impl fmt::Debug for GraphService {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let st = self.gate.lock().unwrap();
        f.debug_struct("GraphService")
            .field("tenants", &st.tenants.len())
            .field("inflight", &st.inflight)
            .field("max_inflight", &self.cfg.max_inflight)
            .field("brownout", &self.brownout.level())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::Dag;
    use std::sync::atomic::AtomicUsize;

    fn service(workers: usize) -> GraphService {
        GraphService::new(ThreadPool::new(workers), ServiceConfig::default())
    }

    #[test]
    fn runs_a_graph_end_to_end_and_counts_it() {
        let svc = service(2);
        let t = svc.register_tenant(TenantSpec::new("solo"));
        let (mut graph, counter) = Dag::diamond_chain(4).to_task_graph(64);
        svc.run(t, &mut graph).unwrap();
        svc.run(t, &mut graph).unwrap();
        assert_eq!(counter.load(Ordering::Relaxed), 2 * 4 * 4);
        let snap = &svc.tenant_snapshots()[0];
        assert_eq!((snap.submitted, snap.completed, snap.failed), (2, 2, 0));
        assert_eq!(snap.inflight, 0, "grant must be released");
        assert!(svc.queue_delay_ewma() > Duration::ZERO, "grants must feed the EWMA");
    }

    #[test]
    fn unknown_tenant_is_rejected() {
        let svc = service(1);
        let other = service(1);
        let foreign = other.register_tenant(TenantSpec::new("x"));
        let (mut graph, _) = Dag::diamond_chain(1).to_task_graph(8);
        assert!(matches!(svc.run(foreign, &mut graph), Err(ServeError::UnknownTenant)));
    }

    #[test]
    fn forced_brownout_sheds_low_but_not_normal() {
        let svc = service(2);
        let low = svc.register_tenant(TenantSpec::new("low").class(RunPriority::Low));
        let normal = svc.register_tenant(TenantSpec::new("normal"));
        svc.brownout.force_level(BrownoutLevel::ShedLow);
        let (mut graph, _) = Dag::diamond_chain(2).to_task_graph(16);
        assert!(matches!(
            svc.run(low, &mut graph),
            Err(ServeError::Shed(ShedReason::Low))
        ));
        svc.brownout.force_level(BrownoutLevel::ShedLow);
        svc.run(normal, &mut graph).unwrap();
        let snaps = svc.tenant_snapshots();
        assert_eq!(snaps[0].shed_low, 1);
        assert_eq!(snaps[1].completed, 1);
    }

    #[test]
    fn many_concurrent_clients_respect_the_tenant_cap() {
        let svc = Arc::new(GraphService::new(
            ThreadPool::new(4),
            ServiceConfig { max_inflight: 64, ..ServiceConfig::default() },
        ));
        let t = svc.register_tenant(TenantSpec::new("capped").max_inflight(2));
        let peak = Arc::new(AtomicUsize::new(0));
        let cur = Arc::new(AtomicUsize::new(0));
        let mut clients = Vec::new();
        for _ in 0..8 {
            let svc = svc.clone();
            let (peak, cur) = (peak.clone(), cur.clone());
            clients.push(std::thread::spawn(move || {
                for _ in 0..4 {
                    let c = cur.clone();
                    let p = peak.clone();
                    let mut g = TaskGraph::new();
                    g.add(move || {
                        let now = c.fetch_add(1, Ordering::SeqCst) + 1;
                        p.fetch_max(now, Ordering::SeqCst);
                        std::thread::sleep(Duration::from_micros(200));
                        c.fetch_sub(1, Ordering::SeqCst);
                    });
                    svc.run(t, &mut g).unwrap();
                }
            }));
        }
        for c in clients {
            c.join().unwrap();
        }
        assert!(
            peak.load(Ordering::SeqCst) <= 2,
            "per-tenant inflight cap must bound concurrency, saw {}",
            peak.load(Ordering::SeqCst)
        );
        assert_eq!(svc.tenant_snapshots()[0].completed, 32);
    }

    #[test]
    fn enqueue_pump_wakes_tickets_it_resolves() {
        use std::sync::mpsc;

        // Regression for the PR 8 lost-wakeup fix. One service slot,
        // held for the whole test; nothing completes, so no release
        // ever pumps — the only thing that can resolve (and must wake)
        // a parked ticket is another caller's enqueue-pump.
        let svc = Arc::new(GraphService::new(
            ThreadPool::new(2),
            ServiceConfig { max_inflight: 1, ..ServiceConfig::default() },
        ));
        let holder = svc.register_tenant(TenantSpec::new("holder"));
        let low = svc.register_tenant(TenantSpec::new("background").class(RunPriority::Low));
        let normal = svc.register_tenant(TenantSpec::new("interactive"));

        // Occupy the single slot with a run parked on a flag.
        let block = Arc::new((Mutex::new(false), Condvar::new()));
        let h = {
            let svc = svc.clone();
            let block = block.clone();
            std::thread::spawn(move || {
                let mut g = TaskGraph::new();
                g.add(move || {
                    let (lock, cv) = &*block;
                    let mut released = lock.lock().unwrap();
                    while !*released {
                        released = cv.wait(released).unwrap();
                    }
                });
                svc.run(holder, &mut g).unwrap();
            })
        };
        while svc.tenant_snapshots()[holder.index()].inflight == 0 {
            std::thread::yield_now();
        }

        // Park the Low tenant behind the held slot.
        let (tx, rx) = mpsc::channel();
        let _b = {
            let svc = svc.clone();
            std::thread::spawn(move || {
                let (mut g, _) = Dag::diamond_chain(1).to_task_graph(8);
                tx.send(svc.run(low, &mut g)).unwrap();
            })
        };
        std::thread::sleep(Duration::from_millis(100)); // let it reach the condvar

        // Escalate, then let an unrelated tenant's *enqueue* shed the
        // parked queue. Only the enqueue-pump's notify can wake the
        // Low caller — before the fix this timed out.
        svc.brownout.force_level(BrownoutLevel::ShedLow);
        let a = {
            let svc = svc.clone();
            std::thread::spawn(move || {
                let (mut g, _) = Dag::diamond_chain(1).to_task_graph(8);
                svc.run(normal, &mut g)
            })
        };

        let shed = rx
            .recv_timeout(Duration::from_secs(10))
            .expect("ticket resolved by another caller's enqueue-pump must wake promptly");
        assert!(matches!(shed, Err(ServeError::Shed(ShedReason::Low))), "got {shed:?}");

        // Release the held slot; the Normal tenant then completes.
        {
            let (lock, cv) = &*block;
            *lock.lock().unwrap() = true;
            cv.notify_all();
        }
        h.join().unwrap();
        a.join().unwrap().unwrap();
        assert_eq!(svc.tenant_snapshots()[low.index()].shed_low, 1);
    }

    #[test]
    fn infeasible_deadline_is_rejected_up_front() {
        let svc = service(2);
        let t = svc.register_tenant(TenantSpec::new("dl"));
        // Heat the gate's EWMA well past the deadline we'll request.
        for _ in 0..8 {
            svc.brownout.observe(Duration::from_millis(50));
        }
        let (mut graph, counter) = Dag::diamond_chain(2).to_task_graph(16);
        let err = svc.run_with(t, &mut graph, Some(Duration::from_millis(1))).unwrap_err();
        assert!(matches!(err, ServeError::Failed(GraphError::WouldMissDeadline)));
        assert_eq!(counter.load(Ordering::Relaxed), 0, "graph must never launch");
        let snap = &svc.tenant_snapshots()[0];
        assert_eq!(snap.shed_deadline, 1);
        assert_eq!(snap.inflight, 0, "rejection must not consume a slot");
    }
}
