//! Retry with backoff, bounded by a goodput-coupled budget (PR 7).
//!
//! Two halves. [`RetryPolicy`] is the per-request schedule: which
//! outcomes are retryable, how many attempts, and an exponential
//! backoff with downward jitter (full-jitter style — the deterministic
//! upper envelope doubles per attempt, the actual delay is drawn
//! uniformly below it, so synchronized clients decorrelate instead of
//! re-storming in lockstep). [`RetryBudget`] is the service-wide
//! brake: a token bucket that refills **as a fraction of goodput**
//! (each success deposits `budget_ratio` of a token), so under
//! *transient* overload there is headroom to retry, while under
//! *permanent* overload successes stop, the bucket drains, and retry
//! amplification is capped at the initial allowance — the classic
//! defense against retry storms turning an overload into an outage.
//!
//! Backoff delays are parked on the `pool/timer.rs` min-heap thread
//! (`GraphService` schedules the wake and the client thread sleeps on
//! a condvar), so a thousand backing-off requests cost a thousand heap
//! entries, not a thousand spinning threads.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::graph::GraphError;

/// Retry schedule applied by [`crate::serve::GraphService`] to
/// `Overloaded` and `DeadlineExceeded` outcomes.
#[derive(Debug, Clone)]
pub struct RetryPolicy {
    /// Total launch attempts per request, including the first
    /// (clamped to ≥ 1; `1` disables retries).
    pub max_attempts: u32,
    /// Backoff envelope before the first retry; doubles per attempt.
    pub base_backoff: Duration,
    /// Cap on the backoff envelope.
    pub max_backoff: Duration,
    /// Fraction of the envelope randomized away (0.0 = deterministic,
    /// 1.0 = full jitter drawing uniformly from (0, envelope]).
    pub jitter: f64,
    /// Retry-budget refill per successful request, in tokens (a retry
    /// spends one token). `0.1` means sustained retry traffic is
    /// capped at 10% of goodput.
    pub budget_ratio: f64,
    /// Tokens available before any success — the allowance that covers
    /// cold-start and transient blips.
    pub initial_budget: u32,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self {
            max_attempts: 4,
            base_backoff: Duration::from_millis(1),
            max_backoff: Duration::from_millis(64),
            jitter: 0.5,
            budget_ratio: 0.1,
            initial_budget: 8,
        }
    }
}

impl RetryPolicy {
    /// No retries: every request gets exactly one launch attempt.
    pub fn disabled() -> Self {
        Self {
            max_attempts: 1,
            initial_budget: 0,
            budget_ratio: 0.0,
            ..Self::default()
        }
    }

    /// Whether `error` is worth retrying: overload and blown deadlines
    /// are load conditions that backoff can outwait; everything else
    /// (cycle, panic, cancel, worker-context misuse) is deterministic
    /// and would fail identically again.
    pub fn retryable(error: &GraphError) -> bool {
        matches!(error, GraphError::Overloaded | GraphError::DeadlineExceeded)
    }

    /// Backoff before retry number `attempt` (1-based: `1` = the delay
    /// between the first failure and the second attempt). `rng_bits`
    /// supplies the jitter draw — pass fresh random bits per call.
    pub fn backoff(&self, attempt: u32, rng_bits: u64) -> Duration {
        let exp = attempt.saturating_sub(1).min(16);
        let envelope = self
            .base_backoff
            .saturating_mul(1u32 << exp)
            .min(self.max_backoff)
            .max(Duration::from_micros(1));
        // Uniform draw in [0, 1) from the top 53 bits.
        let u = (rng_bits >> 11) as f64 / (1u64 << 53) as f64;
        let jitter = self.jitter.clamp(0.0, 1.0);
        envelope.mul_f64(1.0 - jitter * u)
    }
}

/// Milli-token bucket behind the retry budget. Tokens are stored
/// ×1000 so fractional `budget_ratio` refills accumulate exactly.
#[derive(Debug)]
pub(crate) struct RetryBudget {
    tokens_milli: AtomicU64,
    refill_milli: u64,
    cap_milli: u64,
}

impl RetryBudget {
    pub(crate) fn new(policy: &RetryPolicy) -> Self {
        let initial = u64::from(policy.initial_budget) * 1000;
        Self {
            tokens_milli: AtomicU64::new(initial),
            refill_milli: (policy.budget_ratio.clamp(0.0, 1000.0) * 1000.0) as u64,
            // Room to bank a burst allowance beyond the starting
            // tokens, but never unbounded accrual during long calm
            // stretches.
            cap_milli: (initial * 2).max(16_000),
        }
    }

    /// Deposits the per-success refill, saturating at the cap. The
    /// load/store clamp races with concurrent deposits; the budget is
    /// a brake, not a ledger, so losing a fraction of a token to a
    /// race is fine.
    pub(crate) fn on_success(&self) {
        let after = self.tokens_milli.fetch_add(self.refill_milli, Ordering::Relaxed)
            + self.refill_milli;
        if after > self.cap_milli {
            self.tokens_milli.store(self.cap_milli, Ordering::Relaxed);
        }
    }

    /// Takes one whole token if available — the gate each retry must
    /// pass. CAS loop so concurrent takers cannot double-spend.
    pub(crate) fn try_take(&self) -> bool {
        let mut cur = self.tokens_milli.load(Ordering::Relaxed);
        loop {
            if cur < 1000 {
                return false;
            }
            match self.tokens_milli.compare_exchange_weak(
                cur,
                cur - 1000,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return true,
                Err(actual) => cur = actual,
            }
        }
    }

    /// Whole tokens currently available (diagnostics).
    pub(crate) fn tokens(&self) -> u64 {
        self.tokens_milli.load(Ordering::Relaxed) / 1000
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_envelope_doubles_and_caps() {
        let p = RetryPolicy {
            jitter: 0.0,
            base_backoff: Duration::from_millis(1),
            max_backoff: Duration::from_millis(4),
            ..RetryPolicy::default()
        };
        assert_eq!(p.backoff(1, 0), Duration::from_millis(1));
        assert_eq!(p.backoff(2, 0), Duration::from_millis(2));
        assert_eq!(p.backoff(3, 0), Duration::from_millis(4));
        assert_eq!(p.backoff(9, 0), Duration::from_millis(4), "caps at max_backoff");
    }

    #[test]
    fn jitter_only_shrinks_and_stays_positive() {
        let p = RetryPolicy { jitter: 1.0, ..RetryPolicy::default() };
        let envelope = p.backoff(3, 0); // u = 0 -> full envelope
        for bits in [1u64, u64::MAX, 0x1234_5678_9ABC_DEF0] {
            let d = p.backoff(3, bits);
            assert!(d <= envelope, "jitter must not exceed the envelope");
            assert!(d > Duration::ZERO, "jitter must not reach zero");
        }
    }

    #[test]
    fn retryable_is_load_conditions_only() {
        assert!(RetryPolicy::retryable(&GraphError::Overloaded));
        assert!(RetryPolicy::retryable(&GraphError::DeadlineExceeded));
        assert!(!RetryPolicy::retryable(&GraphError::Cancelled));
        assert!(!RetryPolicy::retryable(&GraphError::RunFromWorker));
        assert!(!RetryPolicy::retryable(&GraphError::WouldMissDeadline));
    }

    #[test]
    fn budget_drains_without_successes_and_refills_with_them() {
        let p = RetryPolicy {
            initial_budget: 2,
            budget_ratio: 0.5,
            ..RetryPolicy::default()
        };
        let b = RetryBudget::new(&p);
        assert!(b.try_take());
        assert!(b.try_take());
        assert!(!b.try_take(), "initial allowance exhausted");
        b.on_success(); // +0.5 token
        assert!(!b.try_take(), "half a token is not a token");
        b.on_success();
        assert!(b.try_take(), "two successes at ratio 0.5 buy one retry");
    }

    #[test]
    fn budget_caps_accrual() {
        let p = RetryPolicy {
            initial_budget: 1,
            budget_ratio: 1.0,
            ..RetryPolicy::default()
        };
        let b = RetryBudget::new(&p);
        for _ in 0..100_000 {
            b.on_success();
        }
        assert!(b.tokens() <= 16, "bucket must not accrue unboundedly");
    }
}
