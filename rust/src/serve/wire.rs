//! TCP wire front-end for [`GraphService`] (PR 8).
//!
//! PR 7 built the serving tier deliberately in-process; this module is
//! the promised network skin over it. It adds **no** scheduling policy
//! of its own — every request funnels through the untouched
//! [`GraphService`] gate (DRR fairness, brownout, deadline
//! feasibility, retry budget), so a remote caller gets exactly the
//! same treatment as an in-process one.
//!
//! # Protocol
//!
//! Std-only, length-prefixed binary frames over TCP. Every frame is a
//! big-endian `u32` payload length (≤ [`MAX_FRAME`]) followed by the
//! payload. Request payloads:
//!
//! ```text
//! RUN:    u8 version=1 | u8 kind=1 | u16 token_len | token bytes
//!         | u16 template_len | template bytes | u64 deadline_micros
//!         (deadline_micros = 0 means "tenant default")
//! STATS:  u8 version=1 | u8 kind=2
//! DUMP:   u8 version=1 | u8 kind=3
//! STATS2: u8 version=1 | u8 kind=4
//! ```
//!
//! Response payloads:
//!
//! ```text
//! u8 version=1 | u8 status (WireStatus) | u16 msg_len | msg bytes
//! ```
//!
//! For `RUN`, `msg` carries the error description (empty on OK). For
//! `STATS`, `msg` carries the Prometheus text exposition the metrics
//! listener serves (PR 9 — previously a bare `name value` dump; the
//! sample lines are unchanged, the exposition adds `# HELP`/`# TYPE`
//! headers and histogram families). `DUMP` returns the pool's flight
//! recorder as Chrome-trace JSON — when the full trace exceeds the
//! frame cap, the *oldest* events are halved away until it fits (the
//! drop is accounted in the trace's `overwritten` field). `STATS2`
//! returns the same exposition as `STATS` plus p50/p90/p99 summary
//! gauges derived from the histograms. Graphs are named, not shipped:
//! a request names a **pre-registered template**, and each connection
//! keeps one built [`TaskGraph`] instance per template, so a client
//! issuing the same template repeatedly gets the sealed zero-alloc
//! re-run path end-to-end — the wire adds a frame parse and one
//! syscall pair, not a graph rebuild.
//!
//! An optional second listener answers any HTTP request with a
//! `text/plain` Prometheus exposition (tenant lifecycle counters
//! including the PR 8 `service_ewma_ns` / `demotions`, brownout level
//! and queue-delay EWMA, retry tokens, total observed-rank
//! recomputations, and the PR 9 latency histograms) — a real scrape
//! target without an HTTP dependency. Both the HTTP body and the
//! STATS/STATS2 frames pass [`crate::obs::validate`]; CI enforces
//! this cross-process.
//!
//! The `graph_serve` binary (`rust/src/bin/graph_serve.rs`) wraps this
//! module into a standalone server + client CLI; `benches/serving.rs`
//! `WIRE=1` mode and the CI smoke step drive it cross-process.

use std::collections::HashMap;
use std::io::{self, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::Duration;

use crate::graph::TaskGraph;
use crate::obs::{HistogramSnapshot, PromWriter};
use crate::pool::TenantSnapshot;

use super::brownout::BrownoutLevel;
use super::service::{GraphService, ServeError};
use super::tenant::TenantId;

/// Hard cap on a frame payload (request or response). Large enough for
/// any stats dump we produce, small enough that a garbage length
/// prefix cannot make the server allocate unboundedly.
pub const MAX_FRAME: usize = 64 * 1024;

/// Wire protocol version carried in every payload.
pub const WIRE_VERSION: u8 = 1;

const KIND_RUN: u8 = 1;
const KIND_STATS: u8 = 2;
const KIND_DUMP: u8 = 3;
const KIND_STATS2: u8 = 4;

/// Poll granularity for server-side reads: blocked reads wake this
/// often to check the stop flag, so [`WireHandle::stop`] never hangs
/// on an idle connection.
const READ_POLL: Duration = Duration::from_millis(50);

/// Outcome of one wire request, mirroring [`ServeError`] plus the
/// wire-only failure modes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum WireStatus {
    /// The run completed; all nodes executed exactly once.
    Ok = 0,
    /// Shed at the gate by brownout policy ([`ServeError::Shed`]).
    Shed = 1,
    /// Non-retryable failure ([`ServeError::Failed`]).
    Failed = 2,
    /// Retry budget or attempts exhausted
    /// ([`ServeError::RetriesExhausted`]).
    RetriesExhausted = 3,
    /// The token does not name a registered tenant.
    UnknownTenant = 4,
    /// The request names a template the server does not host.
    UnknownTemplate = 5,
    /// The frame failed to parse (bad version, kind, length, UTF-8).
    BadFrame = 6,
}

impl WireStatus {
    fn from_u8(v: u8) -> Option<Self> {
        Some(match v {
            0 => Self::Ok,
            1 => Self::Shed,
            2 => Self::Failed,
            3 => Self::RetriesExhausted,
            4 => Self::UnknownTenant,
            5 => Self::UnknownTemplate,
            6 => Self::BadFrame,
            _ => return None,
        })
    }
}

type Template = Arc<dyn Fn() -> TaskGraph + Send + Sync>;

/// Builder for the wire front-end: a [`GraphService`] plus the static
/// routing tables (token → tenant, template name → graph factory).
pub struct WireServer {
    svc: Arc<GraphService>,
    tokens: HashMap<String, TenantId>,
    templates: HashMap<String, Template>,
}

impl WireServer {
    /// Starts a builder over `svc`. Tenants must already be registered
    /// with the service; [`WireServer::tenant`] only binds tokens.
    pub fn new(svc: Arc<GraphService>) -> Self {
        Self { svc, tokens: HashMap::new(), templates: HashMap::new() }
    }

    /// Binds an authentication token to a registered tenant.
    pub fn tenant(mut self, token: impl Into<String>, id: TenantId) -> Self {
        self.tokens.insert(token.into(), id);
        self
    }

    /// Registers a graph template. Each connection builds (and then
    /// re-runs, sealed) its own instance on first use.
    pub fn template(
        mut self,
        name: impl Into<String>,
        build: impl Fn() -> TaskGraph + Send + Sync + 'static,
    ) -> Self {
        self.templates.insert(name.into(), Arc::new(build));
        self
    }

    /// Binds the frame listener on `addr` (e.g. `"127.0.0.1:0"`) and
    /// starts accepting. Returns once the socket is listening.
    pub fn serve(self, addr: &str) -> io::Result<WireHandle> {
        self.launch(addr, None)
    }

    /// [`WireServer::serve`] plus a plaintext HTTP metrics listener on
    /// `metrics_addr`.
    pub fn serve_with_metrics(self, addr: &str, metrics_addr: &str) -> io::Result<WireHandle> {
        self.launch(addr, Some(metrics_addr))
    }

    fn launch(self, addr: &str, metrics_addr: Option<&str>) -> io::Result<WireHandle> {
        let listener = TcpListener::bind(addr)?;
        let frame_addr = listener.local_addr()?;
        let shared = Arc::new(Shared {
            svc: self.svc,
            tokens: self.tokens,
            templates: self.templates,
            stop: AtomicBool::new(false),
            reranks: AtomicU64::new(0),
            conns: Mutex::new(Vec::new()),
        });

        let mut accepts = Vec::new();
        {
            let shared = shared.clone();
            accepts.push(thread::spawn(move || accept_loop(&shared, &listener)));
        }

        let metrics = match metrics_addr {
            Some(maddr) => {
                let listener = TcpListener::bind(maddr)?;
                let local = listener.local_addr()?;
                let shared = shared.clone();
                accepts.push(thread::spawn(move || metrics_loop(&shared, &listener)));
                Some(local)
            }
            None => None,
        };

        Ok(WireHandle { shared, frame_addr, metrics_addr: metrics, accepts })
    }
}

/// A running wire front-end. Dropping the handle leaves the server
/// running detached; call [`WireHandle::stop`] for an orderly
/// shutdown.
pub struct WireHandle {
    shared: Arc<Shared>,
    frame_addr: SocketAddr,
    metrics_addr: Option<SocketAddr>,
    accepts: Vec<thread::JoinHandle<()>>,
}

impl WireHandle {
    /// Address the frame listener is bound to (resolves `:0` binds).
    pub fn frame_addr(&self) -> SocketAddr {
        self.frame_addr
    }

    /// Address of the metrics listener, when one was requested.
    pub fn metrics_addr(&self) -> Option<SocketAddr> {
        self.metrics_addr
    }

    /// Stops accepting, wakes every parked connection reader, and
    /// joins all server threads. Open connections are closed at the
    /// next frame boundary (in-flight requests finish first).
    pub fn stop(mut self) {
        self.shared.stop.store(true, Ordering::Release);
        // Poke the accept loops out of their blocking accept().
        let _ = TcpStream::connect(self.frame_addr);
        if let Some(maddr) = self.metrics_addr {
            let _ = TcpStream::connect(maddr);
        }
        for h in self.accepts.drain(..) {
            let _ = h.join();
        }
        let conns = std::mem::take(&mut *self.shared.conns.lock().unwrap());
        for h in conns {
            let _ = h.join();
        }
    }
}

struct Shared {
    svc: Arc<GraphService>,
    tokens: HashMap<String, TenantId>,
    templates: HashMap<String, Template>,
    stop: AtomicBool,
    /// Total observed-rank recomputations across every connection's
    /// template instances (connections fold their per-graph deltas in
    /// after each run).
    reranks: AtomicU64,
    conns: Mutex<Vec<thread::JoinHandle<()>>>,
}

fn accept_loop(shared: &Arc<Shared>, listener: &TcpListener) {
    for stream in listener.incoming() {
        if shared.stop.load(Ordering::Acquire) {
            return;
        }
        let Ok(stream) = stream else { continue };
        let shared2 = shared.clone();
        let h = thread::spawn(move || handle_conn(&shared2, stream));
        shared.conns.lock().unwrap().push(h);
    }
}

fn metrics_loop(shared: &Arc<Shared>, listener: &TcpListener) {
    for stream in listener.incoming() {
        if shared.stop.load(Ordering::Acquire) {
            return;
        }
        let Ok(mut stream) = stream else { continue };
        // Consume whatever request line arrived (contents ignored: any
        // method/path gets the dump), then answer and close.
        let _ = stream.set_read_timeout(Some(READ_POLL));
        let mut scratch = [0u8; 1024];
        let _ = stream.read(&mut scratch);
        let body = render_metrics(shared);
        let head = format!(
            "HTTP/1.0 200 OK\r\nContent-Type: text/plain; charset=utf-8\r\nContent-Length: {}\r\n\r\n",
            body.len()
        );
        let _ = stream.write_all(head.as_bytes());
        let _ = stream.write_all(body.as_bytes());
        let _ = stream.shutdown(Shutdown::Write);
    }
}

/// One connection: a frame loop plus this connection's template
/// instance cache (template name → built graph + last-seen rerank
/// count, so repeated requests hit the sealed re-run path).
fn handle_conn(shared: &Arc<Shared>, mut stream: TcpStream) {
    let _ = stream.set_read_timeout(Some(READ_POLL));
    let _ = stream.set_nodelay(true);
    let mut instances: HashMap<String, (TaskGraph, u64)> = HashMap::new();
    loop {
        let payload = match read_frame(&mut stream, &shared.stop) {
            Ok(Some(p)) => p,
            Ok(None) => return, // clean close or shutdown
            Err(_) => {
                // Oversized or truncated frame: the stream can no
                // longer be trusted to be at a boundary — answer once
                // and close.
                let resp = encode_response(WireStatus::BadFrame, "bad frame");
                let _ = write_frame(&mut stream, &resp);
                let _ = stream.shutdown(Shutdown::Both);
                return;
            }
        };
        let (status, msg) = match decode_request(&payload) {
            None => (WireStatus::BadFrame, "malformed request frame".to_string()),
            Some(WireRequest::Stats) => (WireStatus::Ok, render_metrics(shared)),
            Some(WireRequest::StatsV2) => (WireStatus::Ok, render_stats_v2(shared)),
            Some(WireRequest::Dump) => render_dump(shared),
            Some(WireRequest::Run { token, template, deadline_micros }) => {
                serve_run(shared, &mut instances, &token, &template, deadline_micros)
            }
        };
        let resp = encode_response(status, &msg);
        if write_frame(&mut stream, &resp).is_err() {
            return;
        }
        if status == WireStatus::BadFrame {
            // The stream may be desynchronized; don't try to re-frame.
            let _ = stream.shutdown(Shutdown::Both);
            return;
        }
    }
}

fn serve_run(
    shared: &Shared,
    instances: &mut HashMap<String, (TaskGraph, u64)>,
    token: &str,
    template: &str,
    deadline_micros: u64,
) -> (WireStatus, String) {
    let Some(&tenant) = shared.tokens.get(token) else {
        return (WireStatus::UnknownTenant, format!("unknown tenant token {token:?}"));
    };
    if !instances.contains_key(template) {
        let Some(build) = shared.templates.get(template) else {
            return (WireStatus::UnknownTemplate, format!("unknown template {template:?}"));
        };
        instances.insert(template.to_string(), (build(), 0));
    }
    let (graph, seen_reranks) = instances.get_mut(template).unwrap();
    let deadline = (deadline_micros > 0).then(|| Duration::from_micros(deadline_micros));
    let outcome = shared.svc.run_with(tenant, graph, deadline);
    let now = graph.reranks();
    shared.reranks.fetch_add(now - *seen_reranks, Ordering::Relaxed);
    *seen_reranks = now;
    match outcome {
        Ok(()) => (WireStatus::Ok, String::new()),
        Err(e @ ServeError::Shed(_)) => (WireStatus::Shed, e.to_string()),
        Err(e @ ServeError::RetriesExhausted { .. }) => (WireStatus::RetriesExhausted, e.to_string()),
        Err(e @ ServeError::UnknownTenant) => (WireStatus::UnknownTenant, e.to_string()),
        Err(e @ ServeError::Failed(_)) => (WireStatus::Failed, e.to_string()),
    }
}

/// One labelled sample per tenant, borrowing the label arrays built in
/// [`render_metrics`] (the writer wants `&[(&[(k, v)], value)]`).
fn tenant_series<'a>(
    snaps: &'a [TenantSnapshot],
    labels: &'a [[(&'a str, &'a str); 1]],
    pick: impl Fn(&TenantSnapshot) -> u64,
) -> Vec<(&'a [(&'a str, &'a str)], u64)> {
    snaps.iter().zip(labels.iter()).map(|(t, l)| (l.as_slice(), pick(t))).collect()
}

/// Renders the Prometheus text exposition served by the `STATS` frame
/// kind and the HTTP metrics listener (PR 9). Sample lines keep the
/// exact names and label shapes of the PR 8 plaintext dump (so
/// `tenant_completed{tenant="gold"} 3`-style greps keep working), with
/// `# HELP`/`# TYPE` headers and histogram families layered on top.
fn render_metrics(shared: &Shared) -> String {
    let svc = &shared.svc;
    let mut w = PromWriter::new();
    w.gauge("pool_threads", "Worker threads in the pool.", svc.pool().num_threads() as u64);
    w.gauge("pool_shards", "Worker shards (locality groups).", svc.pool().num_shards() as u64);
    let level = match svc.brownout_level() {
        BrownoutLevel::Normal => 0,
        BrownoutLevel::ShedLow => 1,
        BrownoutLevel::ShedOverQuota => 2,
    };
    w.gauge("brownout_level", "Brownout escalation level (0 = normal).", level);
    w.gauge(
        "queue_delay_ewma_ns",
        "Pool dispatch queue-delay EWMA in nanoseconds.",
        svc.queue_delay_ewma().as_nanos() as u64,
    );
    w.gauge("retry_tokens", "Retry-budget tokens currently available.", svc.retry_tokens() as u64);
    w.counter(
        "graph_reranks_total",
        "Observed-rank recomputations across wire template instances.",
        shared.reranks.load(Ordering::Relaxed),
    );

    let snaps = svc.tenant_snapshots();
    if !snaps.is_empty() {
        let labels: Vec<[(&str, &str); 1]> =
            snaps.iter().map(|t| [("tenant", t.name.as_str())]).collect();
        w.gauge_labeled(
            "tenant_inflight",
            "Runs granted and not yet completed.",
            &tenant_series(&snaps, &labels, |t| t.inflight as u64),
        );
        w.counter_labeled(
            "tenant_submitted",
            "Requests submitted.",
            &tenant_series(&snaps, &labels, |t| t.submitted),
        );
        w.counter_labeled(
            "tenant_completed",
            "Requests completed successfully.",
            &tenant_series(&snaps, &labels, |t| t.completed),
        );
        w.counter_labeled(
            "tenant_retries",
            "Retry attempts.",
            &tenant_series(&snaps, &labels, |t| t.retries),
        );
        w.counter_labeled(
            "tenant_shed_low",
            "Requests shed by brownout Low-class policy.",
            &tenant_series(&snaps, &labels, |t| t.shed_low),
        );
        w.counter_labeled(
            "tenant_shed_over_quota",
            "Requests shed over the per-tenant inflight cap.",
            &tenant_series(&snaps, &labels, |t| t.shed_over_quota),
        );
        w.counter_labeled(
            "tenant_shed_deadline",
            "Requests shed as deadline-infeasible.",
            &tenant_series(&snaps, &labels, |t| t.shed_deadline),
        );
        w.counter_labeled(
            "tenant_failed",
            "Requests failed permanently.",
            &tenant_series(&snaps, &labels, |t| t.failed),
        );
        w.gauge_labeled(
            "tenant_service_ewma_ns",
            "Grant-to-completion service-time EWMA in nanoseconds.",
            &tenant_series(&snaps, &labels, |t| t.service_ewma_ns),
        );
        w.counter_labeled(
            "tenant_demotions",
            "Launches demoted off the tenant's declared class.",
            &tenant_series(&snaps, &labels, |t| t.demotions),
        );
    }

    w.histogram(
        "service_gate_wait_ns",
        "Admission-gate wait (request arrival to dispatch grant).",
        &[],
        &svc.gate_wait_histogram(),
    );
    if let Some(h) = svc.pool().queue_delay_histogram() {
        w.histogram("pool_queue_delay_ns", "Pool dispatch queue delay.", &[], &h);
    }
    if let Some(h) = svc.pool().node_duration_histogram() {
        w.histogram("pool_node_duration_ns", "Graph node execution duration.", &[], &h);
    }
    for (i, (name, snap)) in svc.tenant_latency_histograms().iter().enumerate() {
        if i == 0 {
            w.histogram(
                "tenant_latency_ns",
                "Per-tenant grant-to-completion latency.",
                &[("tenant", name.as_str())],
                snap,
            );
        } else {
            w.histogram_samples("tenant_latency_ns", &[("tenant", name.as_str())], snap);
        }
    }
    w.finish()
}

/// Appends a `{q="..."}`-labelled gauge family of p50/p90/p99 bucket
/// upper bounds for one histogram (the STATS v2 summary lines).
fn push_quantiles(w: &mut PromWriter, name: &str, help: &str, snap: &HistogramSnapshot) {
    w.gauge_labeled(
        name,
        help,
        &[
            (&[("q", "0.5")][..], snap.quantile(0.5)),
            (&[("q", "0.9")][..], snap.quantile(0.9)),
            (&[("q", "0.99")][..], snap.quantile(0.99)),
        ],
    );
}

/// Renders the `STATS2` frame body: the full exposition plus summary
/// quantile gauges (conservative bucket upper bounds, see
/// [`crate::obs::HistogramSnapshot::quantile`]) so a client gets tail
/// numbers without re-deriving them from buckets.
fn render_stats_v2(shared: &Shared) -> String {
    let svc = &shared.svc;
    let mut w = PromWriter::new();
    push_quantiles(
        &mut w,
        "service_gate_wait_ns_quantile",
        "Gate-wait quantiles in nanoseconds.",
        &svc.gate_wait_histogram(),
    );
    if let Some(h) = svc.pool().queue_delay_histogram() {
        push_quantiles(
            &mut w,
            "pool_queue_delay_ns_quantile",
            "Queue-delay quantiles in nanoseconds.",
            &h,
        );
    }
    if let Some(h) = svc.pool().node_duration_histogram() {
        push_quantiles(
            &mut w,
            "pool_node_duration_ns_quantile",
            "Node-duration quantiles in nanoseconds.",
            &h,
        );
    }
    let tenant_hists = svc.tenant_latency_histograms();
    if !tenant_hists.is_empty() {
        let mut rows: Vec<([(&str, &str); 2], u64)> = Vec::new();
        for (name, snap) in &tenant_hists {
            for &(label, q) in &[("0.5", 0.5f64), ("0.9", 0.9), ("0.99", 0.99)] {
                rows.push(([("tenant", name.as_str()), ("q", label)], snap.quantile(q)));
            }
        }
        let samples: Vec<(&[(&str, &str)], u64)> =
            rows.iter().map(|(l, v)| (l.as_slice(), *v)).collect();
        w.gauge_labeled(
            "tenant_latency_ns_quantile",
            "Per-tenant latency quantiles in nanoseconds.",
            &samples,
        );
    }
    let mut out = render_metrics(shared);
    out.push_str(&w.finish());
    out
}

/// Renders the `DUMP` frame body: the flight recorder as Chrome-trace
/// JSON. When the full trace does not fit in one frame, the oldest
/// half of the events is dropped (repeatedly) and accounted as
/// `overwritten` — the newest events are the ones a failure
/// investigation wants.
fn render_dump(shared: &Shared) -> (WireStatus, String) {
    let Some(mut dump) = shared.svc.pool().flight_dump() else {
        return (WireStatus::Failed, "flight recorder disabled on this pool".to_string());
    };
    let mut json = dump.to_chrome_trace();
    while json.len() > MAX_FRAME - 4 && !dump.events.is_empty() {
        let drop_n = (dump.events.len() / 2).max(1);
        dump.events.drain(..drop_n);
        dump.overwritten += drop_n as u64;
        json = dump.to_chrome_trace();
    }
    (WireStatus::Ok, json)
}

// --- framing ------------------------------------------------------------

/// Reads exactly `buf.len()` bytes, riding out read-timeout polls.
/// Returns the count actually read: short only on EOF or a raised stop
/// flag.
fn read_full(stream: &mut TcpStream, buf: &mut [u8], stop: &AtomicBool) -> io::Result<usize> {
    let mut got = 0;
    while got < buf.len() {
        if stop.load(Ordering::Acquire) {
            return Ok(got);
        }
        match stream.read(&mut buf[got..]) {
            Ok(0) => return Ok(got),
            Ok(n) => got += n,
            Err(e)
                if matches!(
                    e.kind(),
                    io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut | io::ErrorKind::Interrupted
                ) => {}
            Err(e) => return Err(e),
        }
    }
    Ok(got)
}

/// Server-side frame read. `Ok(None)` = clean close (EOF at a frame
/// boundary) or stop-flag shutdown; `Err` = garbage (partial frame,
/// oversized length, transport error).
fn read_frame(stream: &mut TcpStream, stop: &AtomicBool) -> io::Result<Option<Vec<u8>>> {
    let mut len_buf = [0u8; 4];
    match read_full(stream, &mut len_buf, stop)? {
        0 => return Ok(None),
        4 => {}
        _ => return Err(io::Error::new(io::ErrorKind::UnexpectedEof, "partial frame header")),
    }
    let len = u32::from_be_bytes(len_buf) as usize;
    if len > MAX_FRAME {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "frame exceeds MAX_FRAME"));
    }
    let mut payload = vec![0u8; len];
    if read_full(stream, &mut payload, stop)? != len {
        return Err(io::Error::new(io::ErrorKind::UnexpectedEof, "partial frame payload"));
    }
    Ok(Some(payload))
}

fn write_frame(stream: &mut TcpStream, payload: &[u8]) -> io::Result<()> {
    debug_assert!(payload.len() <= MAX_FRAME);
    stream.write_all(&(payload.len() as u32).to_be_bytes())?;
    stream.write_all(payload)?;
    stream.flush()
}

// --- payload codec ------------------------------------------------------

pub(crate) enum WireRequest {
    Run { token: String, template: String, deadline_micros: u64 },
    Stats,
    Dump,
    StatsV2,
}

struct Cur<'a> {
    b: &'a [u8],
    p: usize,
}

impl<'a> Cur<'a> {
    fn u8(&mut self) -> Option<u8> {
        let v = *self.b.get(self.p)?;
        self.p += 1;
        Some(v)
    }

    fn u16(&mut self) -> Option<u16> {
        let s = self.b.get(self.p..self.p + 2)?;
        self.p += 2;
        Some(u16::from_be_bytes([s[0], s[1]]))
    }

    fn u64(&mut self) -> Option<u64> {
        let s = self.b.get(self.p..self.p + 8)?;
        self.p += 8;
        Some(u64::from_be_bytes(s.try_into().ok()?))
    }

    fn str(&mut self) -> Option<&'a str> {
        let len = self.u16()? as usize;
        let s = self.b.get(self.p..self.p + len)?;
        self.p += len;
        std::str::from_utf8(s).ok()
    }

    fn done(&self) -> bool {
        self.p == self.b.len()
    }
}

pub(crate) fn encode_run(token: &str, template: &str, deadline_micros: u64) -> Vec<u8> {
    assert!(token.len() <= u16::MAX as usize && template.len() <= u16::MAX as usize);
    let mut p = Vec::with_capacity(14 + token.len() + template.len());
    p.push(WIRE_VERSION);
    p.push(KIND_RUN);
    p.extend_from_slice(&(token.len() as u16).to_be_bytes());
    p.extend_from_slice(token.as_bytes());
    p.extend_from_slice(&(template.len() as u16).to_be_bytes());
    p.extend_from_slice(template.as_bytes());
    p.extend_from_slice(&deadline_micros.to_be_bytes());
    p
}

pub(crate) fn encode_stats() -> Vec<u8> {
    vec![WIRE_VERSION, KIND_STATS]
}

pub(crate) fn encode_dump() -> Vec<u8> {
    vec![WIRE_VERSION, KIND_DUMP]
}

pub(crate) fn encode_stats_v2() -> Vec<u8> {
    vec![WIRE_VERSION, KIND_STATS2]
}

pub(crate) fn decode_request(payload: &[u8]) -> Option<WireRequest> {
    let mut c = Cur { b: payload, p: 0 };
    if c.u8()? != WIRE_VERSION {
        return None;
    }
    match c.u8()? {
        KIND_RUN => {
            let token = c.str()?.to_string();
            let template = c.str()?.to_string();
            let deadline_micros = c.u64()?;
            c.done().then_some(WireRequest::Run { token, template, deadline_micros })
        }
        KIND_STATS => c.done().then_some(WireRequest::Stats),
        KIND_DUMP => c.done().then_some(WireRequest::Dump),
        KIND_STATS2 => c.done().then_some(WireRequest::StatsV2),
        _ => None,
    }
}

pub(crate) fn encode_response(status: WireStatus, msg: &str) -> Vec<u8> {
    let msg = &msg.as_bytes()[..msg.len().min(MAX_FRAME - 4)];
    let mut p = Vec::with_capacity(4 + msg.len());
    p.push(WIRE_VERSION);
    p.push(status as u8);
    p.extend_from_slice(&(msg.len() as u16).to_be_bytes());
    p.extend_from_slice(msg);
    p
}

pub(crate) fn decode_response(payload: &[u8]) -> Option<(WireStatus, String)> {
    let mut c = Cur { b: payload, p: 0 };
    if c.u8()? != WIRE_VERSION {
        return None;
    }
    let status = WireStatus::from_u8(c.u8()?)?;
    let msg = c.str()?.to_string();
    c.done().then_some((status, msg))
}

// --- client -------------------------------------------------------------

/// A persistent client connection. Reuse one across requests to keep
/// the server-side template instance (and its sealed re-run path)
/// warm.
pub struct WireClient {
    stream: TcpStream,
}

impl WireClient {
    /// Connects to a wire front-end's frame listener.
    pub fn connect(addr: impl ToSocketAddrs) -> io::Result<Self> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(Self { stream })
    }

    fn round_trip(&mut self, request: &[u8]) -> io::Result<(WireStatus, String)> {
        write_frame(&mut self.stream, request)?;
        let never = AtomicBool::new(false);
        let payload = read_frame(&mut self.stream, &never)?
            .ok_or_else(|| io::Error::new(io::ErrorKind::UnexpectedEof, "server closed"))?;
        decode_response(&payload)
            .ok_or_else(|| io::Error::new(io::ErrorKind::InvalidData, "malformed response"))
    }

    /// Runs `template` as the tenant named by `token`. `deadline` of
    /// `None` defers to the tenant's default. Transport problems are
    /// `Err`; service-level refusals come back as a [`WireStatus`].
    pub fn run(
        &mut self,
        token: &str,
        template: &str,
        deadline: Option<Duration>,
    ) -> io::Result<(WireStatus, String)> {
        let micros = deadline.map_or(0, |d| d.as_micros().min(u64::MAX as u128) as u64);
        self.round_trip(&encode_run(token, template, micros))
    }

    /// Fetches the Prometheus exposition over the frame protocol.
    pub fn scrape(&mut self) -> io::Result<String> {
        let (status, body) = self.round_trip(&encode_stats())?;
        if status != WireStatus::Ok {
            return Err(io::Error::new(io::ErrorKind::InvalidData, format!("stats: {status:?}")));
        }
        Ok(body)
    }

    /// Fetches the exposition plus p50/p90/p99 summary gauges (the
    /// `STATS2` frame kind, PR 9).
    pub fn scrape_v2(&mut self) -> io::Result<String> {
        let (status, body) = self.round_trip(&encode_stats_v2())?;
        if status != WireStatus::Ok {
            return Err(io::Error::new(io::ErrorKind::InvalidData, format!("stats2: {status:?}")));
        }
        Ok(body)
    }

    /// Fetches the server pool's flight recorder as Chrome-trace JSON
    /// (the `DUMP` frame kind, PR 9). Fails if the server pool was
    /// built with [`crate::pool::PoolConfig::flight_recorder`] off.
    pub fn dump(&mut self) -> io::Result<String> {
        let (status, body) = self.round_trip(&encode_dump())?;
        if status != WireStatus::Ok {
            return Err(io::Error::new(io::ErrorKind::InvalidData, format!("dump: {body}")));
        }
        Ok(body)
    }
}

/// One-shot [`WireClient::run`] on a fresh connection.
pub fn wire_run(
    addr: impl ToSocketAddrs,
    token: &str,
    template: &str,
    deadline: Option<Duration>,
) -> io::Result<(WireStatus, String)> {
    WireClient::connect(addr)?.run(token, template, deadline)
}

/// One-shot [`WireClient::scrape`] on a fresh connection.
pub fn wire_scrape(addr: impl ToSocketAddrs) -> io::Result<String> {
    WireClient::connect(addr)?.scrape()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pool::ThreadPool;
    use crate::serve::{GraphService, ServiceConfig, TenantSpec};
    use crate::workloads::Dag;

    #[test]
    fn payload_codec_roundtrips_and_rejects_garbage() {
        let req = encode_run("tok", "diamond", 1234);
        match decode_request(&req) {
            Some(WireRequest::Run { token, template, deadline_micros }) => {
                assert_eq!((token.as_str(), template.as_str(), deadline_micros), ("tok", "diamond", 1234));
            }
            _ => panic!("RUN did not decode"),
        }
        assert!(matches!(decode_request(&encode_stats()), Some(WireRequest::Stats)));
        assert!(matches!(decode_request(&encode_dump()), Some(WireRequest::Dump)));
        assert!(matches!(decode_request(&encode_stats_v2()), Some(WireRequest::StatsV2)));

        let resp = encode_response(WireStatus::Shed, "brownout");
        assert_eq!(decode_response(&resp), Some((WireStatus::Shed, "brownout".to_string())));

        assert!(decode_request(&[]).is_none(), "empty payload");
        assert!(decode_request(&[99, KIND_RUN]).is_none(), "bad version");
        assert!(decode_request(&[WIRE_VERSION, 77]).is_none(), "bad kind");
        let mut trailing = encode_run("a", "b", 0);
        trailing.push(0);
        assert!(decode_request(&trailing).is_none(), "trailing bytes");
        assert!(decode_response(&[WIRE_VERSION, 200, 0, 0]).is_none(), "bad status");
    }

    #[test]
    fn wire_roundtrip_end_to_end() {
        let svc = Arc::new(GraphService::new(ThreadPool::new(2), ServiceConfig::default()));
        let gold = svc.register_tenant(TenantSpec::new("gold"));
        let handle = WireServer::new(svc.clone())
            .tenant("gold-token", gold)
            .template("diamond", || Dag::diamond_chain(2).to_task_graph(64).0)
            .serve("127.0.0.1:0")
            .unwrap();
        let addr = handle.frame_addr();

        let mut c = WireClient::connect(addr).unwrap();
        for _ in 0..3 {
            let (status, msg) = c.run("gold-token", "diamond", None).unwrap();
            assert_eq!(status, WireStatus::Ok, "{msg}");
        }
        let (status, _) = c.run("gold-token", "no-such-template", None).unwrap();
        assert_eq!(status, WireStatus::UnknownTemplate);
        let (status, _) = c.run("bad-token", "diamond", None).unwrap();
        assert_eq!(status, WireStatus::UnknownTenant);

        let stats = c.scrape().unwrap();
        assert!(stats.contains("tenant_completed{tenant=\"gold\"} 3"), "{stats}");
        assert!(stats.contains("graph_reranks_total "), "{stats}");
        crate::obs::validate(&stats).expect("STATS body must be a valid exposition");
        drop(c);

        // Oversized length prefix: server answers BadFrame, then closes.
        let mut raw = TcpStream::connect(addr).unwrap();
        raw.write_all(&((MAX_FRAME + 1) as u32).to_be_bytes()).unwrap();
        let never = AtomicBool::new(false);
        let resp = read_frame(&mut raw, &never).unwrap().expect("BadFrame response");
        assert_eq!(decode_response(&resp).unwrap().0, WireStatus::BadFrame);
        assert!(read_frame(&mut raw, &never).unwrap().is_none(), "closed after BadFrame");
        drop(raw);

        handle.stop();
        assert_eq!(svc.tenant_snapshots()[gold.index()].completed, 3);
    }

    #[test]
    fn metrics_listener_speaks_plaintext_http() {
        let svc = Arc::new(GraphService::new(ThreadPool::new(2), ServiceConfig::default()));
        let gold = svc.register_tenant(TenantSpec::new("gold"));
        let handle = WireServer::new(svc)
            .tenant("gold", gold)
            .template("d", || Dag::diamond_chain(1).to_task_graph(32).0)
            .serve_with_metrics("127.0.0.1:0", "127.0.0.1:0")
            .unwrap();
        let (status, msg) = wire_run(handle.frame_addr(), "gold", "d", None).unwrap();
        assert_eq!(status, WireStatus::Ok, "{msg}");

        let mut s = TcpStream::connect(handle.metrics_addr().unwrap()).unwrap();
        s.write_all(b"GET /metrics HTTP/1.0\r\n\r\n").unwrap();
        let mut body = String::new();
        s.read_to_string(&mut body).unwrap();
        assert!(body.starts_with("HTTP/1.0 200 OK\r\n"), "{body}");
        assert!(body.contains("pool_threads "), "{body}");
        assert!(body.contains("tenant_completed{tenant=\"gold\"} 1"), "{body}");
        let text = body.split("\r\n\r\n").nth(1).expect("HTTP body after headers");
        crate::obs::validate(text).expect("HTTP scrape must be a valid exposition");
        drop(s);
        handle.stop();
    }

    #[test]
    fn dump_and_stats_v2_frames() {
        let svc = Arc::new(GraphService::new(ThreadPool::new(2), ServiceConfig::default()));
        let gold = svc.register_tenant(TenantSpec::new("gold"));
        let handle = WireServer::new(svc.clone())
            .tenant("gold", gold)
            .template("d", || Dag::diamond_chain(2).to_task_graph(64).0)
            .serve("127.0.0.1:0")
            .unwrap();
        let mut c = WireClient::connect(handle.frame_addr()).unwrap();
        for _ in 0..2 {
            let (status, msg) = c.run("gold", "d", None).unwrap();
            assert_eq!(status, WireStatus::Ok, "{msg}");
        }

        let json = c.dump().unwrap();
        assert!(json.starts_with("{\"traceEvents\":["), "{json}");
        assert!(json.len() <= MAX_FRAME - 4, "dump must fit one frame");
        assert!(json.contains("\"cat\":\"task\""), "dump should contain task spans: {json}");
        assert!(json.contains("\"overwritten\""), "{json}");

        let v2 = c.scrape_v2().unwrap();
        crate::obs::validate(&v2).expect("STATS v2 must be a valid exposition");
        assert!(v2.contains("tenant_completed{tenant=\"gold\"} 2"), "{v2}");
        assert!(v2.contains("tenant_latency_ns_quantile{tenant=\"gold\",q=\"0.99\"}"), "{v2}");
        assert!(v2.contains("service_gate_wait_ns_quantile{q=\"0.5\"}"), "{v2}");
        drop(c);
        handle.stop();
    }
}
