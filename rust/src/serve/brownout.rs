//! Brownout: graceful degradation driven by queue delay (PR 7).
//!
//! A service that accepts everything under sustained overload serves
//! *nobody* well — queues grow without bound and every request misses
//! its deadline. The brownout controller instead watches the one signal
//! that directly measures how far behind the pool is (**queue delay**:
//! time from a request's grant to its launch actually being accepted,
//! folded into an EWMA) and, when it stays high, starts shedding load
//! in a documented order:
//!
//! 1. [`BrownoutLevel::ShedLow`] — requests from `Low`-class tenants
//!    are rejected at the dispatch gate ([`crate::serve::ShedReason::Low`]).
//!    This mirrors PR 6's pool-side Low-shed-first budget policy, one
//!    layer earlier.
//! 2. [`BrownoutLevel::ShedOverQuota`] — additionally, tenants holding
//!    more than their fair share of the service's inflight slots (their
//!    DRR-weight proportion) get their *excess* queue rejected
//!    ([`crate::serve::ShedReason::OverQuota`]). Well-behaved tenants
//!    within quota are untouched.
//!
//! Deadline-infeasible requests (deadline ≤ current queue-delay EWMA)
//! are rejected with [`crate::graph::GraphError::WouldMissDeadline`] at
//! *every* level, including `Normal` — there is no point admitting work
//! that is already guaranteed to be aborted.
//!
//! Recovery is **hysteretic** in both directions so the controller
//! cannot flap: escalation requires `enter_after` *consecutive*
//! over-threshold observations (one bad sample does not brown the
//! service out), and de-escalation steps down one level at a time only
//! after `exit_hold` has elapsed without an over-threshold observation
//! (a clean spell must be sustained, and a two-level brownout takes two
//! holds to fully clear). "Over-threshold" is judged on each **fresh
//! sample**, not the smoothed EWMA — the EWMA exists for deadline
//! feasibility; using it to arm the hold timer would let a single
//! spike storm pin the level high for the filter's whole decay tail
//! (~8 holds) after the queue is already empty.

use std::sync::atomic::{AtomicU32, AtomicU64, AtomicU8, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::obs::{EventKind, FlightRecorder};

/// Current degradation level, in shedding order. Levels are cumulative:
/// `ShedOverQuota` implies `ShedLow`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum BrownoutLevel {
    /// No shedding; all admission decisions are fairness + deadline
    /// feasibility only.
    Normal,
    /// Requests from `Low`-class tenants are shed at the gate.
    ShedLow,
    /// Additionally, queued requests of tenants over their fair
    /// inflight share are shed.
    ShedOverQuota,
}

impl BrownoutLevel {
    fn from_u8(v: u8) -> Self {
        match v {
            0 => Self::Normal,
            1 => Self::ShedLow,
            _ => Self::ShedOverQuota,
        }
    }

    fn as_u8(self) -> u8 {
        match self {
            Self::Normal => 0,
            Self::ShedLow => 1,
            Self::ShedOverQuota => 2,
        }
    }
}

/// Thresholds and hysteresis of the [`BrownoutController`].
#[derive(Debug, Clone)]
pub struct BrownoutConfig {
    /// Queue-delay EWMA above which an observation counts as
    /// over-threshold.
    pub enter: Duration,
    /// Consecutive over-threshold observations required to escalate
    /// one level (clamped to ≥ 1).
    pub enter_after: u32,
    /// Quiet time (no over-threshold observation) required to step
    /// *down* one level.
    pub exit_hold: Duration,
}

impl Default for BrownoutConfig {
    fn default() -> Self {
        Self {
            enter: Duration::from_millis(5),
            enter_after: 8,
            exit_hold: Duration::from_millis(100),
        }
    }
}

/// Hysteretic queue-delay → shedding-level state machine.
///
/// `observe` is called with each fresh queue-delay sample (the service
/// samples on every dispatch grant); `level` is called at each gate
/// decision and lazily applies time-based decay. All state is atomic —
/// both methods are safe to call concurrently from many client
/// threads, and the worst a race can do is delay an escalation or
/// decay by one observation.
#[derive(Debug)]
pub struct BrownoutController {
    cfg: BrownoutConfig,
    /// Base instant for the monotonic nanosecond clock stored in
    /// `last_high_ns` (an `Instant` cannot live in an atomic).
    epoch: Instant,
    /// Queue-delay EWMA, α = 1/8; 0 = no samples yet.
    ewma_ns: AtomicU64,
    /// Consecutive over-threshold observations since the last reset.
    high_streak: AtomicU32,
    /// Current `BrownoutLevel` as u8.
    level: AtomicU8,
    /// Nanoseconds since `epoch` of the most recent over-threshold
    /// observation — the hold timer that gates decay.
    last_high_ns: AtomicU64,
    /// Flight recorder to notify on level transitions (PR 9): every
    /// escalation and decay emits a `Brownout` event (`a` = new level,
    /// `b` = old), so a flight dump shows exactly when the service
    /// started and stopped shedding relative to the scheduler events
    /// around it. `None` when the pool's recorder is disabled.
    flight: Option<Arc<FlightRecorder>>,
}

impl BrownoutController {
    /// Creates a controller at [`BrownoutLevel::Normal`].
    pub fn new(cfg: BrownoutConfig) -> Self {
        Self {
            cfg,
            epoch: Instant::now(),
            ewma_ns: AtomicU64::new(0),
            high_streak: AtomicU32::new(0),
            level: AtomicU8::new(0),
            last_high_ns: AtomicU64::new(0),
            flight: None,
        }
    }

    /// Attaches the pool's flight recorder (PR 9) so level transitions
    /// are recorded alongside the scheduler events. Called once at
    /// service construction, before the controller is shared.
    pub(crate) fn attach_flight(&mut self, flight: Option<Arc<FlightRecorder>>) {
        self.flight = flight;
    }

    /// Emits a `Brownout` transition event on the external lane (gate
    /// callers and `level()` probes are not pool workers).
    fn record_transition(&self, new_level: u8, old_level: u8) {
        if let Some(f) = &self.flight {
            f.record_external(EventKind::Brownout, u32::from(new_level), u64::from(old_level));
        }
    }

    fn now_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    /// Folds one queue-delay sample into the EWMA and updates the
    /// escalation state machine.
    pub fn observe(&self, delay: Duration) {
        let sample = delay.as_nanos() as u64;
        let cur = self.ewma_ns.load(Ordering::Relaxed);
        let next = if cur == 0 {
            sample
        } else {
            // cur + sample/8 - cur/8; exact value is non-critical
            // (racy RMW is fine — this is a smoothing filter).
            cur.wrapping_add(sample / 8).wrapping_sub(cur / 8)
        };
        self.ewma_ns.store(next.max(1), Ordering::Relaxed);

        // The *fresh sample* drives the escalation state machine; the
        // EWMA above only feeds deadline feasibility. Gating the
        // streak/hold on the decayed EWMA (the pre-PR 8 bug) meant one
        // spike storm kept re-arming the hold timer on every later
        // zero-delay sample until the filter drifted back under
        // `enter` — recovery took ~8× `exit_hold` instead of one hold
        // per level.
        if delay > self.cfg.enter {
            self.last_high_ns.store(self.now_ns(), Ordering::Relaxed);
            let streak = self.high_streak.fetch_add(1, Ordering::Relaxed) + 1;
            if streak >= self.cfg.enter_after.max(1) {
                self.high_streak.store(0, Ordering::Relaxed);
                // Escalate one level, saturating at ShedOverQuota.
                if let Ok(old) = self.level.fetch_update(
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                    |l| if l < 2 { Some(l + 1) } else { None },
                ) {
                    self.record_transition(old + 1, old);
                }
            }
        } else {
            self.high_streak.store(0, Ordering::Relaxed);
        }
    }

    /// Current level, after applying hold-based decay: each full
    /// `exit_hold` of quiet (no over-threshold observation) steps the
    /// level down once, restarting the hold so a deep brownout unwinds
    /// gradually rather than all at once.
    pub fn level(&self) -> BrownoutLevel {
        let mut lvl = self.level.load(Ordering::Relaxed);
        if lvl == 0 {
            return BrownoutLevel::Normal;
        }
        let hold = self.cfg.exit_hold.as_nanos() as u64;
        let now = self.now_ns();
        loop {
            let last = self.last_high_ns.load(Ordering::Relaxed);
            if lvl == 0 || now.saturating_sub(last) < hold.max(1) {
                break;
            }
            // One hold elapsed quietly: step down and restart the hold
            // (advance last_high so the next step needs another full
            // hold). CAS on level so concurrent callers decay once.
            match self.level.compare_exchange(
                lvl,
                lvl - 1,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => {
                    let _ = self.last_high_ns.compare_exchange(
                        last,
                        last + hold.max(1),
                        Ordering::Relaxed,
                        Ordering::Relaxed,
                    );
                    self.record_transition(lvl - 1, lvl);
                    lvl -= 1;
                }
                Err(actual) => lvl = actual,
            }
        }
        BrownoutLevel::from_u8(lvl)
    }

    /// Current queue-delay EWMA (zero until the first sample).
    pub fn ewma(&self) -> Duration {
        Duration::from_nanos(self.ewma_ns.load(Ordering::Relaxed))
    }

    /// Test-only: force the controller to a level with the hold timer
    /// freshly armed, so shed behavior can be exercised without
    /// synthesizing sample streams.
    #[cfg(test)]
    pub(crate) fn force_level(&self, level: BrownoutLevel) {
        self.level.store(level.as_u8(), Ordering::Relaxed);
        self.last_high_ns.store(self.now_ns(), Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(enter_ms: u64, enter_after: u32, hold_ms: u64) -> BrownoutConfig {
        BrownoutConfig {
            enter: Duration::from_millis(enter_ms),
            enter_after,
            exit_hold: Duration::from_millis(hold_ms),
        }
    }

    #[test]
    fn starts_normal_and_ignores_single_spikes() {
        let c = BrownoutController::new(cfg(1, 4, 1000));
        assert_eq!(c.level(), BrownoutLevel::Normal);
        // 3 high observations < enter_after=4: no escalation, and a
        // low observation resets the streak.
        for _ in 0..3 {
            c.observe(Duration::from_millis(50));
        }
        assert_eq!(c.level(), BrownoutLevel::Normal);
        for _ in 0..64 {
            c.observe(Duration::ZERO); // drive EWMA back under enter
        }
        for _ in 0..3 {
            c.observe(Duration::from_millis(50));
        }
        assert_eq!(c.level(), BrownoutLevel::Normal, "streak must reset on quiet samples");
    }

    #[test]
    fn sustained_overload_escalates_one_level_at_a_time() {
        let c = BrownoutController::new(cfg(1, 4, 10_000));
        for _ in 0..4 {
            c.observe(Duration::from_millis(50));
        }
        assert_eq!(c.level(), BrownoutLevel::ShedLow);
        for _ in 0..3 {
            c.observe(Duration::from_millis(50));
        }
        assert_eq!(c.level(), BrownoutLevel::ShedLow, "second escalation needs a full streak");
        c.observe(Duration::from_millis(50));
        assert_eq!(c.level(), BrownoutLevel::ShedOverQuota);
        for _ in 0..16 {
            c.observe(Duration::from_millis(50));
        }
        assert_eq!(c.level(), BrownoutLevel::ShedOverQuota, "saturates at the top level");
    }

    #[test]
    fn recovery_is_hysteretic_and_stepwise() {
        // Tiny hold so the test can actually wait it out.
        let c = BrownoutController::new(cfg(1, 1, 20));
        c.observe(Duration::from_millis(50));
        c.observe(Duration::from_millis(50));
        assert_eq!(c.level(), BrownoutLevel::ShedOverQuota);
        // Immediately after the last high observation: no decay yet.
        assert_eq!(c.level(), BrownoutLevel::ShedOverQuota);
        std::thread::sleep(Duration::from_millis(25));
        assert_eq!(c.level(), BrownoutLevel::ShedLow, "one hold unwinds one level");
        std::thread::sleep(Duration::from_millis(25));
        assert_eq!(c.level(), BrownoutLevel::Normal, "second hold fully recovers");
    }

    #[test]
    fn high_traffic_resets_the_hold() {
        let c = BrownoutController::new(cfg(1, 1, 40));
        c.observe(Duration::from_millis(50));
        assert_eq!(c.level(), BrownoutLevel::ShedLow);
        // Keep observing high before the hold elapses: never decays.
        for _ in 0..4 {
            std::thread::sleep(Duration::from_millis(10));
            c.observe(Duration::from_millis(50));
        }
        assert!(c.level() >= BrownoutLevel::ShedLow, "ongoing overload must hold the level");
    }

    #[test]
    fn spike_then_quiet_recovers_in_one_hold_per_level() {
        // Regression (PR 8): two huge spikes escalate to the top level
        // and saturate the EWMA far above `enter` — exactly the state
        // that used to wedge recovery, because every later zero-delay
        // sample re-armed the hold timer off the still-high EWMA.
        let c = BrownoutController::new(cfg(1, 1, 60));
        c.observe(Duration::from_secs(2));
        c.observe(Duration::from_secs(2));
        assert_eq!(c.level(), BrownoutLevel::ShedOverQuota);

        // Stream zero-delay samples; with sample-driven holds these
        // never re-arm the timer, so each elapsed exit_hold steps down
        // one level even while the EWMA is still way over `enter`.
        let quiet_from = Instant::now();
        while quiet_from.elapsed() < Duration::from_millis(95) {
            c.observe(Duration::ZERO);
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(
            c.ewma() > c.cfg.enter,
            "test premise: the EWMA must still be over-threshold while recovery runs"
        );
        assert!(
            c.level() <= BrownoutLevel::ShedLow,
            "one quiet hold must unwind one level, high EWMA or not"
        );
        while quiet_from.elapsed() < Duration::from_millis(220) {
            c.observe(Duration::ZERO);
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(
            c.level(),
            BrownoutLevel::Normal,
            "recovery is bounded at ~one exit_hold per level, not the EWMA decay tail"
        );
    }

    #[test]
    fn ewma_seeds_and_tracks() {
        let c = BrownoutController::new(BrownoutConfig::default());
        assert_eq!(c.ewma(), Duration::ZERO);
        c.observe(Duration::from_millis(8));
        assert_eq!(c.ewma(), Duration::from_millis(8), "first sample seeds the filter");
        c.observe(Duration::ZERO);
        assert!(c.ewma() < Duration::from_millis(8));
        assert!(c.ewma() > Duration::ZERO);
    }
}
