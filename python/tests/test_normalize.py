"""Layer-1 correctness: softmax + layernorm kernels vs oracles, and
the composed L2 graphs (attention scores, transformer FFN)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref
from compile.kernels.normalize import layernorm, softmax


def rand(rng, *shape):
    return rng.uniform(-1.0, 1.0, size=shape).astype(np.float32)


# --------------------------------------------------------------- softmax


@pytest.mark.parametrize("rows,d", [(4, 8), (32, 64), (128, 16)])
def test_softmax_matches_ref(rows, d):
    rng = np.random.default_rng(0)
    x = rand(rng, rows, d) * 5.0
    got = softmax(x, block_rows=min(32, rows))
    np.testing.assert_allclose(got, ref.softmax_ref(x), rtol=1e-5, atol=1e-6)


def test_softmax_rows_sum_to_one():
    rng = np.random.default_rng(1)
    x = rand(rng, 16, 33) * 10.0
    got = np.asarray(softmax(x, block_rows=16))
    np.testing.assert_allclose(got.sum(axis=-1), np.ones(16), rtol=1e-5)
    assert (got >= 0).all()


def test_softmax_stability_large_logits():
    # Stability: huge logits must not overflow (the max-subtraction).
    x = np.array([[1000.0, 1000.0, -1000.0]], dtype=np.float32)
    got = np.asarray(softmax(x, block_rows=1))
    assert np.isfinite(got).all()
    np.testing.assert_allclose(got[0, :2], [0.5, 0.5], atol=1e-6)
    assert got[0, 2] == 0.0


@settings(max_examples=10, deadline=None)
@given(
    rexp=st.integers(0, 5),
    d=st.integers(2, 64),
    seed=st.integers(0, 2**31 - 1),
    scale=st.floats(0.1, 30.0),
)
def test_softmax_hypothesis(rexp, d, seed, scale):
    rows = 2**rexp
    rng = np.random.default_rng(seed)
    x = (rng.uniform(-1, 1, size=(rows, d)) * scale).astype(np.float32)
    got = softmax(x, block_rows=rows)
    np.testing.assert_allclose(got, ref.softmax_ref(x), rtol=1e-4, atol=1e-5)


# ------------------------------------------------------------- layernorm


@pytest.mark.parametrize("rows,d", [(8, 16), (32, 64)])
def test_layernorm_matches_ref(rows, d):
    rng = np.random.default_rng(2)
    x, g, b = rand(rng, rows, d), rand(rng, d), rand(rng, d)
    got = layernorm(x, g, b, block_rows=min(16, rows))
    np.testing.assert_allclose(got, ref.layernorm_ref(x, g, b), rtol=1e-4, atol=1e-5)


def test_layernorm_output_statistics():
    # With unit gamma / zero beta, rows have ~zero mean, ~unit variance.
    rng = np.random.default_rng(3)
    x = rand(rng, 16, 256) * 7.0
    g = np.ones(256, dtype=np.float32)
    b = np.zeros(256, dtype=np.float32)
    got = np.asarray(layernorm(x, g, b, block_rows=16))
    np.testing.assert_allclose(got.mean(axis=-1), np.zeros(16), atol=1e-5)
    np.testing.assert_allclose(got.var(axis=-1), np.ones(16), rtol=1e-2)


@settings(max_examples=8, deadline=None)
@given(rexp=st.integers(0, 4), d=st.integers(4, 96), seed=st.integers(0, 2**31 - 1))
def test_layernorm_hypothesis(rexp, d, seed):
    rows = 2**rexp
    rng = np.random.default_rng(seed)
    x, g, b = rand(rng, rows, d), rand(rng, d), rand(rng, d)
    got = layernorm(x, g, b, block_rows=rows)
    np.testing.assert_allclose(got, ref.layernorm_ref(x, g, b), rtol=1e-3, atol=1e-4)


# ------------------------------------------------------- composed graphs


def test_attention_scores_matches_ref():
    rng = np.random.default_rng(4)
    q, k = rand(rng, 32, 64), rand(rng, 32, 64)
    (got,) = model.attention_scores(q, k)
    np.testing.assert_allclose(got, ref.attention_scores_ref(q, k), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(got).sum(axis=-1), np.ones(32), rtol=1e-5)


def test_transformer_ffn_matches_composed_ref():
    rng = np.random.default_rng(5)
    x = rand(rng, 32, 64)
    gamma, beta = rand(rng, 64), rand(rng, 64)
    w1, b1 = rand(rng, 64, 128), rand(rng, 128)
    w2, b2 = rand(rng, 128, 64), rand(rng, 64)
    (got,) = model.transformer_ffn(x, gamma, beta, w1, b1, w2, b2)
    h = ref.layernorm_ref(x, gamma, beta)
    h = ref.bias_gelu_ref(ref.matmul_ref(h, w1), b1)
    h = ref.bias_gelu_ref(ref.matmul_ref(h, w2), b2)
    np.testing.assert_allclose(got, x + h, rtol=1e-3, atol=1e-4)


def test_transformer_ffn_residual_dominates_at_zero_weights():
    rng = np.random.default_rng(6)
    x = rand(rng, 32, 64)
    gamma, beta = np.ones(64, np.float32), np.zeros(64, np.float32)
    w1 = np.zeros((64, 128), np.float32)
    b1 = np.zeros(128, np.float32)
    w2 = np.zeros((128, 64), np.float32)
    b2 = np.zeros(64, np.float32)
    (got,) = model.transformer_ffn(x, gamma, beta, w1, b1, w2, b2)
    # gelu(0) = 0 -> output == residual input.
    np.testing.assert_allclose(got, x, atol=1e-6)
