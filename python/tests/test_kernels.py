"""Layer-1 correctness: every Pallas kernel vs its pure-jnp oracle.

hypothesis sweeps shapes; fixed-seed numpy data keeps runs
reproducible. Tolerances are f32-tight (the kernels and oracles run
the same math in the same precision)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.elementwise import bias_gelu
from compile.kernels.matmul import matmul, matmul_acc
from compile.kernels.stencil import jacobi_step

RTOL = 1e-5
ATOL = 1e-5


def rand(rng, *shape):
    return rng.uniform(-1.0, 1.0, size=shape).astype(np.float32)


# ---------------------------------------------------------------- matmul


@pytest.mark.parametrize("m,n,k", [(8, 8, 8), (16, 32, 8), (64, 64, 64), (128, 128, 128)])
def test_matmul_acc_matches_ref(m, n, k):
    rng = np.random.default_rng(0)
    a, b, c = rand(rng, m, k), rand(rng, k, n), rand(rng, m, n)
    got = matmul_acc(a, b, c, block_m=min(32, m), block_n=min(32, n), block_k=min(32, k))
    np.testing.assert_allclose(got, ref.matmul_acc_ref(a, b, c), rtol=RTOL, atol=ATOL)


def test_matmul_zero_acc_equals_plain():
    rng = np.random.default_rng(1)
    a, b = rand(rng, 32, 16), rand(rng, 16, 32)
    np.testing.assert_allclose(
        matmul(a, b, block_m=16, block_n=16, block_k=16),
        ref.matmul_ref(a, b),
        rtol=RTOL,
        atol=ATOL,
    )


def test_matmul_multiblock_k_accumulates():
    # k split across 4 grid steps must equal single-block result.
    rng = np.random.default_rng(2)
    a, b, c = rand(rng, 16, 64), rand(rng, 64, 16), rand(rng, 16, 16)
    multi = matmul_acc(a, b, c, block_m=16, block_n=16, block_k=16)
    single = matmul_acc(a, b, c, block_m=16, block_n=16, block_k=64)
    np.testing.assert_allclose(multi, single, rtol=RTOL, atol=ATOL)


@settings(max_examples=12, deadline=None)
@given(
    mexp=st.integers(2, 5),
    nexp=st.integers(2, 5),
    kexp=st.integers(2, 5),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_acc_hypothesis_pow2_shapes(mexp, nexp, kexp, seed):
    m, n, k = 2**mexp, 2**nexp, 2**kexp
    rng = np.random.default_rng(seed)
    a, b, c = rand(rng, m, k), rand(rng, k, n), rand(rng, m, n)
    bm, bn, bk = min(8, m), min(8, n), min(8, k)
    got = matmul_acc(a, b, c, block_m=bm, block_n=bn, block_k=bk)
    np.testing.assert_allclose(got, ref.matmul_acc_ref(a, b, c), rtol=1e-4, atol=1e-4)


def test_matmul_rejects_indivisible_blocks():
    rng = np.random.default_rng(3)
    a, b, c = rand(rng, 12, 12), rand(rng, 12, 12), rand(rng, 12, 12)
    with pytest.raises(AssertionError):
        matmul_acc(a, b, c, block_m=8, block_n=8, block_k=8)


# ------------------------------------------------------------- bias_gelu


@pytest.mark.parametrize("rows,d", [(8, 16), (32, 64), (128, 32)])
def test_bias_gelu_matches_ref(rows, d):
    rng = np.random.default_rng(4)
    x, b = rand(rng, rows, d), rand(rng, d)
    got = bias_gelu(x, b, block_rows=min(32, rows))
    np.testing.assert_allclose(got, ref.bias_gelu_ref(x, b), rtol=RTOL, atol=ATOL)


@settings(max_examples=10, deadline=None)
@given(
    rexp=st.integers(0, 5),
    dexp=st.integers(0, 6),
    seed=st.integers(0, 2**31 - 1),
    scale=st.floats(0.1, 10.0),
)
def test_bias_gelu_hypothesis(rexp, dexp, seed, scale):
    rows, d = 2**rexp, 2**dexp
    rng = np.random.default_rng(seed)
    x = (rng.uniform(-1, 1, size=(rows, d)) * scale).astype(np.float32)
    b = rand(rng, d)
    got = bias_gelu(x, b, block_rows=rows)
    np.testing.assert_allclose(got, ref.bias_gelu_ref(x, b), rtol=1e-4, atol=1e-4)


def test_bias_gelu_known_values():
    # gelu(0) = 0; gelu(large) ~ large; gelu(-large) ~ 0.
    x = np.array([[0.0, 10.0, -10.0]], dtype=np.float32)
    b = np.zeros(3, dtype=np.float32)
    got = np.asarray(bias_gelu(x, b, block_rows=1))
    assert abs(got[0, 0]) < 1e-6
    assert abs(got[0, 1] - 10.0) < 1e-3
    assert abs(got[0, 2]) < 1e-3


# ---------------------------------------------------------------- jacobi


@pytest.mark.parametrize("n", [3, 8, 64])
def test_jacobi_matches_ref(n):
    rng = np.random.default_rng(5)
    g = rand(rng, n, n)
    np.testing.assert_allclose(jacobi_step(g), ref.jacobi_ref(g), rtol=RTOL, atol=ATOL)


def test_jacobi_boundary_fixed():
    rng = np.random.default_rng(6)
    g = rand(rng, 16, 16)
    out = np.asarray(jacobi_step(g))
    np.testing.assert_array_equal(out[0, :], g[0, :])
    np.testing.assert_array_equal(out[-1, :], g[-1, :])
    np.testing.assert_array_equal(out[:, 0], g[:, 0])
    np.testing.assert_array_equal(out[:, -1], g[:, -1])


def test_jacobi_converges_on_laplace():
    # Repeated relaxation with zero boundary decays the interior.
    rng = np.random.default_rng(7)
    g = rand(rng, 16, 16)
    g[0, :] = g[-1, :] = g[:, 0] = g[:, -1] = 0.0
    before = np.abs(g[1:-1, 1:-1]).max()
    out = g
    for _ in range(50):
        out = np.asarray(jacobi_step(out))
    after = np.abs(out[1:-1, 1:-1]).max()
    assert after < before * 0.25


@settings(max_examples=8, deadline=None)
@given(n=st.integers(3, 48), seed=st.integers(0, 2**31 - 1))
def test_jacobi_hypothesis(n, seed):
    rng = np.random.default_rng(seed)
    g = rand(rng, n, n)
    np.testing.assert_allclose(jacobi_step(g), ref.jacobi_ref(g), rtol=1e-4, atol=1e-4)
