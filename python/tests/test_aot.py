"""Build-path tests: aot.py lowering + manifest round-trip.

Lowers a small subset of the export table into a temp dir and checks
the HLO text and manifest invariants the Rust registry relies on."""

import os

import pytest

from compile import aot


def test_render_spec():
    import jax

    s = jax.ShapeDtypeStruct((3, 4), aot.F32)
    assert aot.render_spec(s) == "f32[3,4]"
    scalar = jax.ShapeDtypeStruct((), aot.F32)
    assert aot.render_spec(scalar) == "f32[]"


def test_exports_table_well_formed():
    assert len(aot.EXPORTS) >= 6
    for name, (fn, in_specs) in aot.EXPORTS.items():
        assert callable(fn), name
        assert len(in_specs) >= 1, name
        # Names must be valid artifact-file stems (no separators).
        assert "/" not in name and "\t" not in name


@pytest.fixture(scope="module")
def lowered(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    rows = {}
    for name in ["axpy_256", "matmul_tile_32"]:
        fn, in_specs = aot.EXPORTS[name]
        row, nbytes = aot.lower_one(name, fn, in_specs, str(out))
        assert nbytes > 0
        rows[name] = row
    return out, rows


def test_lower_one_writes_hlo_text(lowered):
    out, rows = lowered
    for name in rows:
        path = os.path.join(str(out), f"{name}.hlo.txt")
        assert os.path.exists(path)
        text = open(path).read()
        # HLO text module header; ENTRY computation present.
        assert text.startswith("HloModule"), text[:40]
        assert "ENTRY" in text


def test_manifest_rows_match_registry_grammar(lowered):
    _out, rows = lowered
    row = rows["matmul_tile_32"]
    cols = row.split("\t")
    assert len(cols) == 4
    name, fname, ins, outs = cols
    assert name == "matmul_tile_32"
    assert fname == "matmul_tile_32.hlo.txt"
    assert ins == "f32[32,32];f32[32,32];f32[32,32]"
    assert outs == "f32[32,32]"


def test_axpy_scalar_spec(lowered):
    _out, rows = lowered
    ins = rows["axpy_256"].split("\t")[2]
    assert ins == "f32[];f32[256];f32[256]"


def test_hlo_text_has_no_mosaic_custom_call(lowered):
    # interpret=True must lower to plain HLO — a Mosaic/tpu custom-call
    # would be unloadable by the CPU PJRT plugin.
    out, rows = lowered
    for name in rows:
        text = open(os.path.join(str(out), f"{name}.hlo.txt")).read()
        assert "tpu_custom_call" not in text
        assert "mosaic" not in text.lower()
