"""Layer-2 correctness: model graphs vs composed oracles + shapes."""

import numpy as np
import pytest

from compile import model
from compile.kernels import ref


def rand(rng, *shape):
    return rng.uniform(-1.0, 1.0, size=shape).astype(np.float32)


def test_matmul_tile_matches_ref():
    rng = np.random.default_rng(0)
    a, b, c = rand(rng, 64, 64), rand(rng, 64, 64), rand(rng, 64, 64)
    (got,) = model.matmul_tile(a, b, c)
    np.testing.assert_allclose(got, ref.matmul_acc_ref(a, b, c), rtol=1e-5, atol=1e-5)


def test_mlp_layer_matches_ref():
    rng = np.random.default_rng(1)
    x, w, b = rand(rng, 32, 64), rand(rng, 64, 128), rand(rng, 128)
    (got,) = model.mlp_layer(x, w, b)
    assert got.shape == (32, 128)
    np.testing.assert_allclose(got, ref.mlp_layer_ref(x, w, b), rtol=1e-4, atol=1e-4)


def test_mlp2_composition():
    rng = np.random.default_rng(2)
    x = rand(rng, 32, 64)
    w1, b1 = rand(rng, 64, 128), rand(rng, 128)
    w2, b2 = rand(rng, 128, 64), rand(rng, 64)
    (got,) = model.mlp2(x, w1, b1, w2, b2)
    assert got.shape == (32, 64)
    np.testing.assert_allclose(got, ref.mlp2_ref(x, w1, b1, w2, b2), rtol=1e-4, atol=1e-4)


def test_wavefront_step_residual():
    rng = np.random.default_rng(3)
    g = rand(rng, 64, 64)
    out, residual = model.wavefront_step(g)
    np.testing.assert_allclose(out, ref.jacobi_ref(g), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(residual, np.abs(np.asarray(out) - g).max(), rtol=1e-5, atol=1e-6)


def test_wavefront_fixed_point_residual_zero():
    g = np.ones((8, 8), dtype=np.float32)
    out, residual = model.wavefront_step(g)
    np.testing.assert_allclose(out, g)
    assert float(residual) == 0.0


def test_axpy():
    rng = np.random.default_rng(4)
    x, y = rand(rng, 256), rand(rng, 256)
    (got,) = model.axpy(np.float32(2.5), x, y)
    np.testing.assert_allclose(got, 2.5 * x + y, rtol=1e-6, atol=1e-6)


def test_mlp_layer_rejects_inner_dim_mismatch():
    rng = np.random.default_rng(5)
    x = rand(rng, 32, 63)  # inner dim 63 != w's 64
    w, b = rand(rng, 64, 128), rand(rng, 128)
    with pytest.raises(Exception):
        model.mlp_layer(x, w, b)


def test_mlp_layer_rejects_bias_mismatch():
    rng = np.random.default_rng(6)
    x, w = rand(rng, 32, 64), rand(rng, 64, 128)
    b = rand(rng, 127)
    with pytest.raises(Exception):
        model.mlp_layer(x, w, b)
