"""AOT lowering: JAX/Pallas -> HLO text + manifest.tsv.

Run once at build time (`make artifacts`); Python never appears on the
request path. The interchange format is HLO **text**, not a serialized
HloModuleProto: jax >= 0.5 emits protos with 64-bit instruction ids
that the Rust side's xla_extension 0.5.1 rejects (`proto.id() <=
INT_MAX`), while the text parser reassigns ids and round-trips cleanly.

Manifest format (tab-separated, parsed by rust/src/runtime/registry.rs):

    name <TAB> file <TAB> inputs <TAB> outputs

with arg specs like ``f32[64,64]`` joined by ``;``.

Usage: python -m compile.aot --out ../artifacts
"""

import argparse
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

F32 = jnp.float32


def spec(*dims):
    """ShapeDtypeStruct for an f32 array."""
    return jax.ShapeDtypeStruct(tuple(dims), F32)


def render_spec(s) -> str:
    return "f32[{}]".format(",".join(str(d) for d in s.shape))


# Exported entry points: name -> (fn, input specs).
# Sizes are chosen so interpret-mode tracing stays fast while tiles
# remain MXU-multiple-shaped where it matters (see DESIGN.md §Perf).
EXPORTS = {
    # Blocked-matmul inner step at the tile sizes the L3 workloads use.
    "matmul_tile_32": (model.matmul_tile, [spec(32, 32)] * 3),
    "matmul_tile_64": (model.matmul_tile, [spec(64, 64)] * 3),
    "matmul_tile_128": (model.matmul_tile, [spec(128, 128)] * 3),
    # MLP layers for the serving example: batch 32.
    "mlp_layer_64x128": (model.mlp_layer, [spec(32, 64), spec(64, 128), spec(128)]),
    "mlp_layer_128x64": (model.mlp_layer, [spec(32, 128), spec(128, 64), spec(64)]),
    "mlp2_64": (
        model.mlp2,
        [spec(32, 64), spec(64, 128), spec(128), spec(128, 64), spec(64)],
    ),
    # Wavefront node body.
    "jacobi_64": (model.wavefront_step, [spec(64, 64)]),
    # Attention scores (matmul + softmax kernels composed).
    "attention_scores_32x64": (model.attention_scores, [spec(32, 64), spec(32, 64)]),
    # Pre-LN transformer FFN block (layernorm + 2x matmul + 2x gelu).
    "transformer_ffn_64": (
        model.transformer_ffn,
        [spec(32, 64), spec(64), spec(64), spec(64, 128), spec(128), spec(128, 64), spec(64)],
    ),
    # Runtime smoke test.
    "axpy_256": (model.axpy, [spec(), spec(256), spec(256)]),
}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_one(name, fn, in_specs, out_dir):
    lowered = jax.jit(fn).lower(*in_specs)
    text = to_hlo_text(lowered)
    fname = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    # Output specs from the jitted signature.
    out_aval = lowered.out_info
    flat, _ = jax.tree_util.tree_flatten(out_aval)
    outs = ";".join(render_spec(o) for o in flat)
    ins = ";".join(render_spec(s) for s in in_specs)
    return f"{name}\t{fname}\t{ins}\t{outs}", len(text)


def main() -> int:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts", help="output directory")
    parser.add_argument(
        "--only", default=None, help="comma-separated subset of export names"
    )
    args = parser.parse_args()

    os.makedirs(args.out, exist_ok=True)
    names = list(EXPORTS)
    if args.only:
        names = [n for n in names if n in set(args.only.split(","))]

    rows = ["# name\tfile\tinputs\toutputs"]
    for name in names:
        fn, in_specs = EXPORTS[name]
        row, nbytes = lower_one(name, fn, in_specs, args.out)
        rows.append(row)
        print(f"  {name}: {nbytes} bytes of HLO text")
    manifest = os.path.join(args.out, "manifest.tsv")
    with open(manifest, "w") as f:
        f.write("\n".join(rows) + "\n")
    print(f"wrote {manifest} ({len(names)} entries)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
