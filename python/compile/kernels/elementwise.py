"""Layer-1 Pallas kernel: fused bias + GeLU.

The elementwise epilogue of an MLP layer as a single VMEM-resident
kernel (one load, one store per element; the five-op GeLU chain fuses
in-register). Grid over row blocks so arbitrarily large batches stream
through a bounded VMEM footprint.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_SQRT_2_OVER_PI = 0.7978845608028654


def _bias_gelu_kernel(x_ref, b_ref, o_ref):
    z = x_ref[...] + b_ref[...]
    inner = _SQRT_2_OVER_PI * (z + 0.044715 * z * z * z)
    o_ref[...] = 0.5 * z * (1.0 + jnp.tanh(inner))


def bias_gelu(x, b, *, block_rows: int = 128):
    """``gelu(x + b)`` (tanh approximation), x: (rows, d), b: (d,)."""
    rows, d = x.shape
    assert b.shape == (d,), f"bias shape {b.shape} != ({d},)"
    br = min(block_rows, rows)
    assert rows % br == 0, f"rows {rows} not divisible by block {br}"
    return pl.pallas_call(
        _bias_gelu_kernel,
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), jnp.float32),
        interpret=True,
    )(x, b)
