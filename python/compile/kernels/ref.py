"""Pure-jnp oracles for every Pallas kernel.

pytest compares each kernel against these references (the CORE
correctness signal for Layer 1); the Rust side re-verifies end-to-end
against its own host-math references.
"""

import jax.numpy as jnp


def matmul_acc_ref(a, b, c):
    """C' = A @ B + C (the blocked-matmul inner step)."""
    return jnp.dot(a, b, preferred_element_type=jnp.float32) + c


def matmul_ref(a, b):
    """Plain matmul."""
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


def bias_gelu_ref(x, b):
    """y = gelu(x + b) with the tanh approximation (matches kernel)."""
    z = x + b
    return (
        0.5
        * z
        * (1.0 + jnp.tanh(jnp.sqrt(2.0 / jnp.pi) * (z + 0.044715 * z**3)))
    )


def jacobi_ref(grid):
    """One 5-point Jacobi relaxation step with fixed boundary.

    interior[i,j] = 0.25 * (up + down + left + right); edges unchanged.
    """
    grid = jnp.asarray(grid)  # accept numpy inputs (tests feed ndarray)
    up = grid[:-2, 1:-1]
    down = grid[2:, 1:-1]
    left = grid[1:-1, :-2]
    right = grid[1:-1, 2:]
    interior = 0.25 * (up + down + left + right)
    return grid.at[1:-1, 1:-1].set(interior)


def softmax_ref(x):
    """Numerically-stable row softmax."""
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def layernorm_ref(x, gamma, beta, eps=1e-5):
    """Row LayerNorm with affine parameters."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * gamma + beta


def attention_scores_ref(q, k):
    """Scaled dot-product scores + softmax: softmax(q @ k.T / sqrt(d))."""
    d = q.shape[-1]
    return softmax_ref(jnp.dot(q, k.T, preferred_element_type=jnp.float32) / jnp.sqrt(d))


def mlp_layer_ref(x, w, b):
    """One MLP layer: gelu(x @ w + b)."""
    return bias_gelu_ref(matmul_ref(x, w), b)


def mlp2_ref(x, w1, b1, w2, b2):
    """Two stacked MLP layers (the L2 composition check)."""
    return mlp_layer_ref(mlp_layer_ref(x, w1, b1), w2, b2)
