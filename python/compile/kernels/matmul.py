"""Layer-1 Pallas kernels: tiled matmul with accumulation.

TPU-shaped even though we validate on CPU (interpret=True): the grid
iterates (M/bm, N/bn) output tiles with an in-kernel K loop over
(bm, bk) x (bk, bn) VMEM blocks, accumulating in an f32 scratch tile —
the HBM<->VMEM schedule a Mosaic compile would pipeline. Block sizes
default to MXU-friendly multiples; DESIGN.md §Perf carries the VMEM
footprint accounting (3 tiles: bm*bk + bk*bn + bm*bn floats).

interpret=True is mandatory on this testbed: real TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute; interpret mode
lowers to plain HLO so the same computation runs natively from Rust.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_acc_kernel(a_ref, b_ref, c_ref, o_ref, *, nk: int):
    """One (bm, bn) output tile: o = sum_k a[:, k] @ b[k, :] + c."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = c_ref[...]

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


def matmul_acc(a, b, c, *, block_m: int = 128, block_n: int = 128, block_k: int = 128):
    """``A @ B + C`` as a Pallas call with a 3-D (m, n, k) grid.

    The k axis is the innermost ("arbitrary" order) grid dimension;
    o_ref is revisited across k steps, giving the accumulation loop.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims mismatch: {a.shape} @ {b.shape}"
    assert c.shape == (m, n), f"bad accumulator shape {c.shape}"
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"shape ({m},{n},{k}) not divisible by blocks ({bm},{bn},{bk})"
    )
    nk = k // bk
    kernel = functools.partial(_matmul_acc_kernel, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b, c)


def matmul(a, b, **kw):
    """Plain ``A @ B`` via the same kernel with a zero accumulator."""
    m, n = a.shape[0], b.shape[1]
    return matmul_acc(a, b, jnp.zeros((m, n), jnp.float32), **kw)
