"""Layer-1 Pallas kernel: 5-point Jacobi relaxation step.

One wavefront-style grid update: interior cells become the average of
their four neighbours, boundary cells are fixed. The kernel takes the
whole grid as a single VMEM block (grids used by the task-graph
workloads are tile-sized, e.g. 64x64 = 16 KiB — comfortably VMEM-
resident); shifted reads express the neighbour accesses that a Mosaic
compile would turn into register rotates.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _jacobi_kernel(g_ref, o_ref):
    g = g_ref[...]
    up = g[:-2, 1:-1]
    down = g[2:, 1:-1]
    left = g[1:-1, :-2]
    right = g[1:-1, 2:]
    interior = 0.25 * (up + down + left + right)
    out = g.at[1:-1, 1:-1].set(interior)
    o_ref[...] = out


def jacobi_step(grid):
    """One Jacobi step over a (n, n) grid with fixed boundary."""
    n, n2 = grid.shape
    assert n == n2, f"square grids only, got {grid.shape}"
    assert n >= 3, "grid too small for a 5-point stencil"
    return pl.pallas_call(
        _jacobi_kernel,
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=True,
    )(grid)
