"""Layer-1 Pallas kernels: row softmax and LayerNorm.

Both are row-parallel reductions: the grid tiles the batch dimension,
each kernel invocation keeps one block of rows VMEM-resident and does
the full reduce-then-normalize dance in registers — the structure that
matters on TPU (a single HBM round-trip per row instead of three for
the naive max/sub-exp/sum decomposition).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _softmax_kernel(x_ref, o_ref):
    x = x_ref[...]
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    o_ref[...] = e / jnp.sum(e, axis=-1, keepdims=True)


def softmax(x, *, block_rows: int = 128):
    """Numerically-stable row softmax, x: (rows, d)."""
    rows, d = x.shape
    br = min(block_rows, rows)
    assert rows % br == 0, f"rows {rows} not divisible by block {br}"
    return pl.pallas_call(
        _softmax_kernel,
        grid=(rows // br,),
        in_specs=[pl.BlockSpec((br, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), jnp.float32),
        interpret=True,
    )(x)


def _layernorm_kernel(x_ref, g_ref, b_ref, o_ref, *, eps: float):
    x = x_ref[...]
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    norm = (x - mu) * jax.lax.rsqrt(var + eps)
    o_ref[...] = norm * g_ref[...] + b_ref[...]


def layernorm(x, gamma, beta, *, eps: float = 1e-5, block_rows: int = 128):
    """Row LayerNorm with affine params, x: (rows, d), gamma/beta: (d,)."""
    import functools

    rows, d = x.shape
    assert gamma.shape == (d,) and beta.shape == (d,)
    br = min(block_rows, rows)
    assert rows % br == 0, f"rows {rows} not divisible by block {br}"
    return pl.pallas_call(
        functools.partial(_layernorm_kernel, eps=eps),
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), jnp.float32),
        interpret=True,
    )(x, gamma, beta)
