"""Layer-2: JAX compute graphs calling the Layer-1 Pallas kernels.

These are the functions `aot.py` lowers to HLO text; the Rust runtime
executes them by artifact name. Everything here traces through the
Pallas kernels (interpret=True) so the kernels land inside the same
HLO module — one compiled executable per exported entry point.
"""

import jax.numpy as jnp

from .kernels.elementwise import bias_gelu
from .kernels.matmul import matmul, matmul_acc
from .kernels.normalize import layernorm, softmax
from .kernels.stencil import jacobi_step


def matmul_tile(a, b, c):
    """Blocked-matmul inner step: ``A @ B + C`` on one tile.

    The L3 blocked-matmul task graph calls this once per (i, j, k);
    the accumulator threading keeps the k-loop on the Rust side so the
    graph can schedule it.
    """
    return (matmul_acc(a, b, c),)


def mlp_layer(x, w, b):
    """One MLP layer ``gelu(x @ w + b)`` — matmul kernel + fused
    bias/GeLU epilogue kernel."""
    return (bias_gelu(matmul(x, w), b),)


def mlp2(x, w1, b1, w2, b2):
    """Two stacked MLP layers in one executable (the L2 composition:
    XLA fuses the inter-layer boundary)."""
    h = bias_gelu(matmul(x, w1), b1)
    return (bias_gelu(matmul(h, w2), b2),)


def wavefront_step(grid):
    """One Jacobi relaxation step (the wavefront workload's node body).

    Also returns the interior residual so the L3 driver can check
    convergence without a second kernel launch.
    """
    out = jacobi_step(grid)
    residual = jnp.max(jnp.abs(out - grid))
    return (out, residual)


def attention_scores(q, k):
    """Scaled dot-product attention scores: softmax(q @ k.T / sqrt(d)).

    Two L1 kernels composed in one L2 graph (matmul + softmax); the
    transpose and scale fold into XLA between them.
    """
    d = q.shape[-1]
    scores = matmul(q, jnp.transpose(k)) / jnp.sqrt(jnp.float32(d))
    return (softmax(scores),)


def transformer_ffn(x, gamma, beta, w1, b1, w2, b2):
    """Pre-LN transformer feed-forward block:
    ``x + mlp2(layernorm(x))`` — four L1 kernels in one executable."""
    h = layernorm(x, gamma, beta)
    h = bias_gelu(matmul(h, w1), b1)
    h = bias_gelu(matmul(h, w2), b2)
    return (x + h,)


def axpy(alpha, x, y):
    """``alpha * x + y`` — the trivial smoke-test entry point used by
    runtime integration tests (fast to execute, exercises scalars)."""
    return (alpha * x + y,)
