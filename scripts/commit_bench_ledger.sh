#!/usr/bin/env bash
# Roll CI-measured medians into the committed bench ledger.
#
# The committed BENCH_pr10.json starts life with null medians: the
# bench-smoke regression gate treats null-baseline rows as NEW (they
# pass), so the gate only arms once real CI-hardware medians are
# committed back. This script closes that loop: it downloads the
# ledger artifact from a green bench-smoke run, shows the diff against
# the committed ledger, and commits the measured numbers.
#
# Usage:
#   scripts/commit_bench_ledger.sh [RUN_ID]
#
# With no RUN_ID, the artifact from the latest successful ci run on
# the current branch is used. Requires the GitHub CLI (`gh`) with repo
# access; run from anywhere inside the checkout.
set -euo pipefail

LEDGER=BENCH_pr10.json
cd "$(git rev-parse --show-toplevel)"

if ! command -v gh >/dev/null 2>&1; then
    echo "error: this script needs the GitHub CLI (gh)" >&2
    exit 1
fi

run_id="${1:-}"
if [[ -z "$run_id" ]]; then
    branch="$(git rev-parse --abbrev-ref HEAD)"
    run_id="$(gh run list --workflow ci --branch "$branch" --status success \
        --limit 1 --json databaseId --jq '.[0].databaseId')"
    if [[ -z "$run_id" || "$run_id" == "null" ]]; then
        echo "error: no successful ci run found on branch '$branch'" >&2
        exit 1
    fi
    echo "using latest green ci run on '$branch': $run_id"
fi

tmp="$(mktemp -d)"
trap 'rm -rf "$tmp"' EXIT
gh run download "$run_id" --name "$LEDGER" --dir "$tmp"

if [[ ! -f "$tmp/$LEDGER" ]]; then
    echo "error: run $run_id has no '$LEDGER' artifact (did bench-smoke run?)" >&2
    exit 1
fi

python3 - "$LEDGER" "$tmp/$LEDGER" <<'EOF'
import json, sys
committed, fetched = (json.load(open(p)) for p in sys.argv[1:3])
key = lambda e: (e['bench'], e['title'], e['param'], e['series'], e['metric'], e['threads'])
old = {key(e): e.get('median_ns') for e in committed.get('entries', [])}
armed = stale = 0
for e in fetched.get('entries', []):
    prev = old.get(key(e))
    cur = e.get('median_ns')
    if prev is None and cur is not None:
        armed += 1
        print(f"ARM  {e['bench']}/{e['param']}/{e['series']}: {cur} ns")
    elif prev is not None and cur is not None and prev != cur:
        stale += 1
        print(f"DIFF {e['bench']}/{e['param']}/{e['series']}: {prev} -> {cur} ns")
print(f"{armed} row(s) newly armed, {stale} row(s) re-measured")
EOF

cp "$tmp/$LEDGER" "$LEDGER"
if git diff --quiet -- "$LEDGER"; then
    echo "committed ledger already matches run $run_id — nothing to do"
    exit 0
fi

git add "$LEDGER"
git commit -m "Commit CI-measured bench medians from run $run_id

Arms the bench-smoke regression gate for the rows measured on CI
hardware; previously-null baselines diffed as NEW and could not fail."
echo "committed — push to arm the regression gate"
